//! Block-scale selection strategies.
//!
//! The NVFP4 default maps each 16-element block's amax to the top node 6
//! (`s = amax/6`). Because the grid is non-uniform, that is not MSE-optimal
//! for every block: mapping amax to node 4 instead (`s = amax/4`) densifies
//! the low end at the cost of clipping nothing (amax still representable,
//! now at node 6's slot... the 4/6 trade — paper baseline [23]), and a
//! small scale *search* around amax/6 does better still (our strong
//! baseline; DESIGN.md §7).

use crate::config::ScaleMethod;
use crate::formats::codec::{self, Prepared};
use crate::formats::{e2m1, e4m3, nvfp4};
use crate::tensor::Tensor;

/// Effective elementwise scales for `w[..., K, N]` under a method.
/// Returns (scale tensor, per-slice global scales).
pub fn scales_for(w: &Tensor, method: ScaleMethod) -> (Tensor, Vec<f32>) {
    match method {
        ScaleMethod::Standard => nvfp4::standard_scales(w),
        ScaleMethod::FourSix => four_six_scales(w),
        ScaleMethod::Search => search_scales(w),
    }
}

/// Build the NVFP4 interval context for `w` under a scale method — the
/// single entry point pipeline code uses (no `Prepared` construction
/// outside `formats/`).
pub fn prepare_with_method(w: &Tensor, method: ScaleMethod) -> Prepared {
    let (scale, s_global) = scales_for(w, method);
    codec::prepare_with_scales(w, scale, s_global)
}

/// Block MSE of RTN quantization for a candidate *effective* scale.
/// `block` iterates the 16 values of one (block, column) group.
fn block_mse(block: &[f32], s_eff: f32) -> f64 {
    if s_eff <= 0.0 {
        return block.iter().map(|&x| (x as f64).powi(2)).sum();
    }
    let mut acc = 0.0f64;
    for &x in block {
        let wt = (x.abs() / s_eff).min(e2m1::FP4_MAX);
        let q = e2m1::decode(e2m1::encode_rtn(wt)) * s_eff;
        let err = x.abs() - q;
        acc += (err as f64) * (err as f64);
    }
    acc
}

fn gather_block(ws: &[f32], kb: usize, col: usize, n: usize) -> [f32; nvfp4::BLOCK] {
    let mut out = [0.0f32; nvfp4::BLOCK];
    for (r, o) in out.iter_mut().enumerate() {
        *o = ws[(kb * nvfp4::BLOCK + r) * n + col];
    }
    out
}

/// Generic chooser: for each block, evaluate candidate raw scales (as
/// multiples of amax) and keep the MSE-best, E4M3 effects included.
fn choose_scales(w: &Tensor, candidates: &[f32]) -> (Tensor, Vec<f32>) {
    let (k, n) = w.mat_dims().expect("rank >= 2");
    let lead = w.lead();
    let slice_len = k * n;
    let mut chosen = vec![0.0f32; lead * (k / nvfp4::BLOCK) * n];

    // first pass: per-slice global scale from the *standard* recipe so the
    // E4M3 encoding stays in range for every candidate <= amax/4
    let mut s_globals = Vec::with_capacity(lead);
    for l in 0..lead {
        let ws = &w.data[l * slice_len..(l + 1) * slice_len];
        let amax_tot = ws.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        s_globals.push((amax_tot / (e2m1::FP4_MAX * e4m3::E4M3_MAX)).max(1e-30));
    }

    for l in 0..lead {
        let ws = &w.data[l * slice_len..(l + 1) * slice_len];
        let s_g = s_globals[l];
        for kb in 0..k / nvfp4::BLOCK {
            for col in 0..n {
                let block = gather_block(ws, kb, col, n);
                let amax = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if amax == 0.0 {
                    continue; // chosen stays 0
                }
                let mut best = f64::INFINITY;
                let mut best_raw = amax / e2m1::FP4_MAX;
                for &c in candidates {
                    let raw = amax * c;
                    // what the hardware actually sees after E4M3:
                    let s_eff = e4m3::roundtrip(raw / s_g) * s_g;
                    let m = block_mse(&block, s_eff);
                    if m < best {
                        best = m;
                        best_raw = raw;
                    }
                }
                chosen[l * (k / nvfp4::BLOCK) * n + kb * n + col] = best_raw;
            }
        }
    }

    let scale = nvfp4::effective_scales(w, |l, kb, col, _amax| {
        chosen[l * (k / nvfp4::BLOCK) * n + kb * n + col]
    });
    (scale.0, scale.1)
}

/// "4/6" adaptive block scaling: per block, map amax to node 6 OR node 4,
/// whichever gives lower block MSE. (Candidates 1/6 and 1/4 of amax.)
pub fn four_six_scales(w: &Tensor) -> (Tensor, Vec<f32>) {
    choose_scales(w, &[1.0 / 6.0, 1.0 / 4.0])
}

/// Strong-baseline scale search: 9 candidates spanning [amax/6.6, amax/4].
pub fn search_scales(w: &Tensor) -> (Tensor, Vec<f32>) {
    const CANDS: [f32; 9] = [
        1.0 / 6.6,
        1.0 / 6.3,
        1.0 / 6.0,
        1.0 / 5.7,
        1.0 / 5.4,
        1.0 / 5.0,
        1.0 / 4.6,
        1.0 / 4.3,
        1.0 / 4.0,
    ];
    choose_scales(w, &CANDS)
}

/// Total RTN quantization MSE of a weight tensor under a scale method —
/// used by tests and the ablation bench.
pub fn rtn_mse(w: &Tensor, method: ScaleMethod) -> f64 {
    let p = prepare_with_method(w, method);
    let q = codec::rtn_quant(w, &p);
    crate::util::stats::mse(&q.data, &w.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, 0.05);
        t
    }

    #[test]
    fn four_six_never_worse_than_standard() {
        for seed in 0..5 {
            let w = rand_w(&[64, 32], seed);
            let std_mse = rtn_mse(&w, ScaleMethod::Standard);
            let fs_mse = rtn_mse(&w, ScaleMethod::FourSix);
            assert!(
                fs_mse <= std_mse * 1.0001,
                "seed {seed}: 4/6 {fs_mse} > standard {std_mse}"
            );
        }
    }

    #[test]
    fn search_never_worse_than_four_six() {
        for seed in 0..5 {
            let w = rand_w(&[64, 32], seed + 10);
            let fs = rtn_mse(&w, ScaleMethod::FourSix);
            let se = rtn_mse(&w, ScaleMethod::Search);
            assert!(se <= fs * 1.0001, "seed {seed}: search {se} > 4/6 {fs}");
        }
    }

    #[test]
    fn search_strictly_helps_on_gaussian() {
        // averaged over blocks, the search must find real improvements
        let w = rand_w(&[256, 64], 99);
        let std_mse = rtn_mse(&w, ScaleMethod::Standard);
        let se_mse = rtn_mse(&w, ScaleMethod::Search);
        assert!(se_mse < std_mse * 0.995, "search {se_mse} vs standard {std_mse}");
    }

    #[test]
    fn block_structure_preserved() {
        let w = rand_w(&[32, 8], 3);
        let (s, _) = four_six_scales(&w);
        for col in 0..8 {
            for r in 1..16 {
                assert_eq!(s.data[r * 8 + col], s.data[col]);
            }
        }
    }

    #[test]
    fn zero_tensor_safe() {
        let w = Tensor::zeros(&[32, 8]);
        for m in [ScaleMethod::Standard, ScaleMethod::FourSix, ScaleMethod::Search] {
            let (s, sg) = scales_for(&w, m);
            assert!(s.data.iter().all(|x| x.is_finite()));
            assert!(sg.iter().all(|x| *x > 0.0));
            assert_eq!(rtn_mse(&w, m), 0.0);
        }
    }

    #[test]
    fn stacked_tensor_shapes() {
        let w = rand_w(&[2, 32, 16], 5);
        let (s, sg) = four_six_scales(&w);
        assert_eq!(s.shape, w.shape);
        assert_eq!(sg.len(), 2);
    }
}
