//! Calibration: capture per-linear input activations from the frozen
//! full-precision model via the `lm_capture` artifact, and build the
//! stage-1 row sets + GPTQ Hessians from them.
//!
//! The capture artifact returns one stacked tensor per capture point
//! (`attn_in`, `attn_o_in`, `mlp_in`, `mlp_down_in`) with shape
//! [L, B, T, F]. Each quantized linear is mapped to its capture point by
//! the manifest (wq/wk/wv share `attn_in`, etc.).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::data::{batcher::Split, Batcher, Corpus};
use crate::gptq::Hessian;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;
use crate::train::ParamStore;
use crate::util::rng::Rng;

/// Calibration data for one capture point.
pub struct CaptureSet {
    /// per-layer stage-1 row matrices [R, F]
    pub rows: Vec<Tensor>,
    /// per-layer input Hessians (for GPTQ)
    pub hessians: Vec<Hessian>,
}

/// All capture points.
pub struct Calibration {
    /// capture sets keyed by capture-point name
    pub sets: BTreeMap<String, CaptureSet>,
    /// calibration batches that were captured
    pub n_batches: usize,
}

impl Calibration {
    /// The capture set for one capture point, or error.
    pub fn set(&self, capture: &str) -> Result<&CaptureSet> {
        self.sets.get(capture).ok_or_else(|| anyhow!("no capture set '{capture}'"))
    }
}

/// Run `n_batches` calibration batches through the frozen model and
/// collect rows + Hessians. Stage-1 rows are reservoir-subsampled to
/// `rows_per_layer` (deterministic by seed).
pub fn capture(
    rt: &Runtime,
    corpora: &[&Corpus],
    params: &ParamStore,
    n_batches: usize,
    rows_per_layer: usize,
    seed: u64,
) -> Result<Calibration> {
    let cfg = rt.config().clone();
    let spec = rt.manifest.artifact("lm_capture")?.clone();
    // calibration draws round-robin from the corpus mixture so the learned
    // rounding doesn't overfit one eval distribution (paper calibrates on
    // general text; see EXPERIMENTS.md)
    let batchers: Vec<Batcher> = corpora
        .iter()
        .map(|c| Batcher::new(c, Split::Calib, cfg.eval_batch, cfg.seq_len, seed))
        .collect();

    // feature dim per capture point, from the artifact's output specs
    let mut feat: BTreeMap<String, usize> = BTreeMap::new();
    for out in &spec.outputs {
        feat.insert(out.name.clone(), *out.shape.last().unwrap());
    }

    // reservoirs: per capture point, per layer
    struct Reservoir {
        rows: Vec<f32>,
        f: usize,
        cap: usize,
        seen: usize,
        rng: Rng,
    }
    impl Reservoir {
        fn push(&mut self, row: &[f32]) {
            if self.rows.len() < self.cap * self.f {
                self.rows.extend_from_slice(row);
            } else {
                let j = self.rng.below(self.seen + 1);
                if j < self.cap {
                    self.rows[j * self.f..(j + 1) * self.f].copy_from_slice(row);
                }
            }
            self.seen += 1;
        }
    }

    let mut reservoirs: BTreeMap<String, Vec<Reservoir>> = BTreeMap::new();
    let mut hessians: BTreeMap<String, Vec<Hessian>> = BTreeMap::new();
    for (name, &f) in &feat {
        reservoirs.insert(
            name.clone(),
            (0..cfg.n_layers)
                .map(|l| Reservoir {
                    rows: vec![],
                    f,
                    cap: rows_per_layer,
                    seen: 0,
                    rng: Rng::new(seed ^ (l as u64) << 32 ^ fnv(name)),
                })
                .collect(),
        );
        hessians.insert(name.clone(), (0..cfg.n_layers).map(|_| Hessian::new(f)).collect());
    }

    let mut args = params.values();
    args.push(Value::I32(vec![], vec![])); // placeholder, replaced per batch
    let tok_idx = args.len() - 1;

    for b in 0..n_batches {
        args[tok_idx] = batchers[b % batchers.len()].batch_at(b);
        let outputs = rt.exec("lm_capture", &args)?;
        for (out, ospec) in outputs.iter().zip(&spec.outputs) {
            let t = out.as_tensor()?;
            let f = feat[&ospec.name];
            let rows_per_l: usize = t.numel() / cfg.n_layers / f;
            let res = reservoirs.get_mut(&ospec.name).unwrap();
            let hes = hessians.get_mut(&ospec.name).unwrap();
            for l in 0..cfg.n_layers {
                let base = l * rows_per_l * f;
                let slice = &t.data[base..base + rows_per_l * f];
                hes[l]
                    .update(&Tensor::new(slice.to_vec(), vec![rows_per_l, f]))?;
                for r in 0..rows_per_l {
                    res[l].push(&slice[r * f..(r + 1) * f]);
                }
            }
        }
    }

    let mut sets = BTreeMap::new();
    for (name, res) in reservoirs {
        let f = feat[&name];
        let rows = res
            .into_iter()
            .map(|r| {
                let n = r.rows.len() / f;
                Tensor::new(r.rows, vec![n, f])
            })
            .collect();
        sets.insert(
            name.clone(),
            CaptureSet { rows, hessians: hessians.remove(&name).unwrap() },
        );
    }
    Ok(Calibration { sets, n_batches })
}

/// Pad or trim a row matrix to exactly `target` rows (cycling) — stage-1
/// artifacts are shape-specialized to cfg.stage1_rows.
pub fn fit_rows(x: &Tensor, target: usize) -> Tensor {
    let (r, f) = x.mat_dims().unwrap();
    if r == target {
        return x.clone();
    }
    let mut data = Vec::with_capacity(target * f);
    for i in 0..target {
        let src = i % r.max(1);
        data.extend_from_slice(&x.data[src * f..(src + 1) * f]);
    }
    Tensor::new(data, vec![target, f])
}

fn fnv(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_rows_pads_and_trims() {
        let x = Tensor::new((0..6).map(|i| i as f32).collect(), vec![3, 2]);
        let padded = fit_rows(&x, 5);
        assert_eq!(padded.shape, vec![5, 2]);
        assert_eq!(&padded.data[6..8], &[0.0, 1.0]); // cycled
        let trimmed = fit_rows(&x, 2);
        assert_eq!(trimmed.shape, vec![2, 2]);
        assert_eq!(trimmed.data, &x.data[..4]);
        assert_eq!(fit_rows(&x, 3).data, x.data);
    }
}
