//! Fused nibble-decode + matmul kernels over packed weights.
//!
//! The native backend's whole linear stack funnels through
//! [`Linear::matvec`]: `y += x @ W[l]` for one `[K] → [N]` layer slice,
//! where `W` stays in its 4-bit packed form and every element is decoded
//! *inside* the GEMM inner loop — two table lookups and a multiply per
//! weight, via [`BlockDecode`]. No dense f32 copy of a quantized layer
//! ever materializes on the serving path.
//!
//! Layout intuition: codes are packed row-major two-per-byte along the
//! output (`N`) axis, so the kernel walks `y += x[row] * W[row, :]`
//! row by row — each row is one contiguous byte run, each 16/32-row
//! block shares one decoded scale row. Per-element work:
//!
//! ```text
//! y[j] += xv * elem_lut[nibble] * scale_row[j]
//! ```
//!
//! When the caller allows it (decode at batch 1 — never nested under the
//! backend's per-slot fan-out), large matvecs split their output columns
//! across [`threads::par_map`] workers; every column is accumulated by
//! exactly one worker in row order, so parallel results are bitwise
//! identical to scalar results regardless of worker count.

use anyhow::{bail, Result};

use crate::formats::codec::{BlockDecode, DecodeTables, QuantTensor};
use crate::tensor::Tensor;
use crate::util::threads;

/// MAC count above which a single matvec fans out across threads.
pub const PAR_MACS: usize = 1 << 18;

/// Register-block tile height for [`Linear::matmul`]: activation rows
/// processed per pass over the packed payload. Each packed byte is read
/// and LUT-decoded once per tile and applied to all `TILE_M` rows, so a
/// `[M, K]` batch touches the payload `ceil(M / TILE_M)` times instead
/// of `M` times.
pub const TILE_M: usize = 8;

/// A packed layer stack plus its precomputed decode tables, so the GEMM
/// hot loop builds its [`BlockDecode`] view with a memcpy instead of
/// re-deriving 272 LUT entries per call.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    q: QuantTensor,
    tables: DecodeTables,
}

impl PackedLinear {
    /// Wrap a packed payload, precomputing its format's decode tables.
    pub fn new(q: QuantTensor) -> PackedLinear {
        let tables = q.format.decode_tables();
        PackedLinear { q, tables }
    }

    /// The packed payload.
    pub fn quant(&self) -> &QuantTensor {
        &self.q
    }
}

/// One weight stack (`[L, K, N]` or `[K, N]`) in whichever form it is
/// held: packed 4-bit (the quantized linears) or dense f32 (the
/// embedding/norm/head parameters and any non-quantized fallback).
#[derive(Clone, Debug)]
pub enum Linear {
    /// dense f32 weights
    Dense(Tensor),
    /// packed 4-bit payload, decoded on the fly inside the GEMM loop
    Packed(PackedLinear),
}

impl From<QuantTensor> for Linear {
    fn from(q: QuantTensor) -> Linear {
        Linear::Packed(PackedLinear::new(q))
    }
}

impl Linear {
    /// Contraction (input) dimension.
    pub fn k(&self) -> usize {
        let shape = self.shape();
        shape[shape.len() - 2]
    }

    /// Output dimension.
    pub fn n(&self) -> usize {
        let shape = self.shape();
        shape[shape.len() - 1]
    }

    /// The full weight shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Linear::Dense(t) => &t.shape,
            Linear::Packed(p) => &p.q.shape,
        }
    }

    /// True when the layer is held packed.
    pub fn is_packed(&self) -> bool {
        matches!(self, Linear::Packed(_))
    }

    /// Packed payload bytes (0 for dense layers).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Linear::Dense(_) => 0,
            Linear::Packed(p) => p.q.payload_bytes(),
        }
    }

    /// `y += x @ W[l]` for slice `l`: `x` is `[K]`, `y` is `[N]`.
    ///
    /// `scratch` holds the decoded scale row between calls so the hot
    /// loop never allocates. `workers > 1` allows the column-parallel
    /// path for matvecs above [`PAR_MACS`]; callers already inside a
    /// batch fan-out pass 1 so thread pools never nest. Accumulation is
    /// plain f32 in row order — bitwise identical between the scalar and
    /// column-parallel paths.
    pub fn matvec(
        &self,
        l: usize,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut Vec<f32>,
        workers: usize,
    ) -> Result<()> {
        let (k, n) = (self.k(), self.n());
        if x.len() != k || y.len() != n {
            bail!("matvec: x[{}] @ W[{k}, {n}] -> y[{}]", x.len(), y.len());
        }
        match self {
            Linear::Dense(t) => {
                let base = l * k * n;
                for (row, &xv) in x.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &t.data[base + row * n..base + (row + 1) * n];
                    for (yj, &w) in y.iter_mut().zip(wrow) {
                        *yj += xv * w;
                    }
                }
                Ok(())
            }
            Linear::Packed(p) => {
                let dec = p.q.block_decode_cached(&p.tables)?;
                if workers > 1 && k * n >= PAR_MACS {
                    return matvec_packed_par(&dec, l, x, y, workers);
                }
                scratch.resize(n, 0.0);
                matvec_packed_cols(&dec, l, x, y, 0, n, scratch);
                Ok(())
            }
        }
    }

    /// Multi-row fused GEMM: `Y[M, N] += X[M, K] @ W[l]`, both row-major.
    ///
    /// The packed path tiles over M in blocks of [`TILE_M`]: each packed
    /// byte is read and nibble-decoded **once per tile** and applied to
    /// every activation row in the tile, and each block-scale row is
    /// decoded once per (block, tile) — where `M` calls to
    /// [`Self::matvec`] would stream and decode the whole payload `M`
    /// times. Accumulation stays column-in-row-order per output row with
    /// the exact op order of `matvec` (`(x * elem) * scale`, zero inputs
    /// skipped), so every output row is **bitwise identical** to the
    /// matvec of its input row — `M = 1` is a drop-in replacement.
    ///
    /// `scratch` and `workers` behave as in [`Self::matvec`]; the
    /// column-parallel split engages above [`PAR_MACS`] total MACs and
    /// each column is still accumulated by one worker in row order.
    pub fn matmul(
        &self,
        l: usize,
        x: &[f32],
        m: usize,
        y: &mut [f32],
        scratch: &mut Vec<f32>,
        workers: usize,
    ) -> Result<()> {
        let (k, n) = (self.k(), self.n());
        if x.len() != m * k || y.len() != m * n {
            bail!(
                "matmul: x[{}] @ W[{k}, {n}] -> y[{}] for m={m} rows",
                x.len(),
                y.len()
            );
        }
        if m == 0 {
            return Ok(());
        }
        match self {
            Linear::Dense(t) => {
                matmul_dense_rows(&t.data[l * k * n..(l + 1) * k * n], x, m, k, n, y);
                Ok(())
            }
            Linear::Packed(p) => {
                let dec = p.q.block_decode_cached(&p.tables)?;
                if workers > 1 && m * k * n >= PAR_MACS {
                    return matmul_packed_par(&dec, l, x, m, y, workers);
                }
                scratch.resize(n, 0.0);
                matmul_packed_cols(&dec, l, x, m, y, 0, n, scratch);
                Ok(())
            }
        }
    }
}

/// Dense multi-row GEMM, tiled over M so each weight row is loaded once
/// per tile. Per output row the accumulation order and op order are
/// exactly the dense `matvec` path's (`y[j] += x * w`, rows in order,
/// zero inputs skipped), so rows match matvec bitwise.
fn matmul_dense_rows(w: &[f32], x: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    let mut tile = 0;
    while tile < m {
        let tm = (m - tile).min(TILE_M);
        for row in 0..k {
            let mut xs = [0.0f32; TILE_M];
            let mut any = false;
            for (mi, xv) in xs.iter_mut().enumerate().take(tm) {
                *xv = x[(tile + mi) * k + row];
                any |= *xv != 0.0;
            }
            if !any {
                continue;
            }
            let wrow = &w[row * n..(row + 1) * n];
            for (j, &wv) in wrow.iter().enumerate() {
                for (mi, &xv) in xs.iter().enumerate().take(tm) {
                    if xv == 0.0 {
                        continue;
                    }
                    y[(tile + mi) * n + j] += xv * wv;
                }
            }
        }
        tile += TILE_M;
    }
}

/// The fused inner loop over an output-column range `[c0, c1)`:
/// `y[0..c1-c0] += x @ W[l, :, c0..c1]`, decoding nibbles and block
/// scales in place. `scale_row` is `c1 - c0` long — each worker decodes
/// only its own chunk's scales. `c0` and `c1` must be even (nibble pairs
/// share a byte).
fn matvec_packed_cols(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let block = dec.block();
    for kb in 0..dec.block_rows() {
        dec.scale_range_into(l, kb, c0, c1, scale_row);
        for r in 0..block {
            let row = kb * block + r;
            let xv = x[row];
            if xv == 0.0 {
                continue;
            }
            let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
            for (j2, &b) in bytes.iter().enumerate() {
                let j = 2 * j2;
                y[j] += xv * dec.elem(b & 0x0F) * scale_row[j];
                y[j + 1] += xv * dec.elem(b >> 4) * scale_row[j + 1];
            }
        }
    }
}

/// Nibble-aligned output-column ranges for a `workers`-way split —
/// shared by the column-parallel matvec and matmul so the alignment
/// rule lives in exactly one place.
fn col_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let chunk = ((n.div_ceil(workers) + 1) & !1).max(2);
    (0..n).step_by(chunk).map(|c0| (c0, (c0 + chunk).min(n))).collect()
}

/// Column-parallel fused matvec: output columns are split into
/// nibble-aligned ranges, one worker per range; each column is still
/// accumulated sequentially in row order, so the result is bitwise
/// identical to the scalar path.
fn matvec_packed_par(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    y: &mut [f32],
    workers: usize,
) -> Result<()> {
    let ranges = col_ranges(dec.n(), workers);
    let parts = threads::par_map(ranges.clone(), workers, |(c0, c1)| {
        let mut part = vec![0.0f32; c1 - c0];
        let mut scale_row = vec![0.0f32; c1 - c0];
        matvec_packed_cols(dec, l, x, &mut part, c0, c1, &mut scale_row);
        part
    });
    for ((c0, c1), part) in ranges.into_iter().zip(parts) {
        for (j, v) in (c0..c1).zip(part) {
            y[j] += v;
        }
    }
    Ok(())
}

/// The multi-row fused inner loop over an output-column range `[c0, c1)`:
/// `y[mi, 0..c1-c0] += x[mi, :] @ W[l, :, c0..c1]` for all `m` rows,
/// with `y` laid out `[m, c1 - c0]` row-major. M is tiled in blocks of
/// [`TILE_M`]; within a tile each packed byte is loaded and
/// nibble-decoded once, each scale row once per (block, tile), and the
/// decoded values applied to every tile row. Per output row the element
/// op order matches [`matvec_packed_cols`] exactly. `c0`/`c1` must be
/// even (nibble pairs share a byte).
fn matmul_packed_cols(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    m: usize,
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let (block, k, w) = (dec.block(), dec.k(), c1 - c0);
    let mut tile = 0;
    while tile < m {
        let tm = (m - tile).min(TILE_M);
        for kb in 0..dec.block_rows() {
            // one scale-row decode per (block, tile) — amortized over
            // every row and every payload byte of the block
            dec.scale_range_into(l, kb, c0, c1, scale_row);
            for r in 0..block {
                let row = kb * block + r;
                // gather the tile's activation column for this K row
                let mut xs = [0.0f32; TILE_M];
                let mut any = false;
                for (mi, xv) in xs.iter_mut().enumerate().take(tm) {
                    *xv = x[(tile + mi) * k + row];
                    any |= *xv != 0.0;
                }
                if !any {
                    continue;
                }
                let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
                for (j2, &b) in bytes.iter().enumerate() {
                    let j = 2 * j2;
                    // one byte load + two LUT decodes, applied to all
                    // tm rows (matvec pays these per row)
                    let e0 = dec.elem(b & 0x0F);
                    let e1 = dec.elem(b >> 4);
                    let s0 = scale_row[j];
                    let s1 = scale_row[j + 1];
                    for (mi, &xv) in xs.iter().enumerate().take(tm) {
                        if xv == 0.0 {
                            continue;
                        }
                        let yo = (tile + mi) * w + j;
                        y[yo] += xv * e0 * s0;
                        y[yo + 1] += xv * e1 * s1;
                    }
                }
            }
        }
        tile += TILE_M;
    }
}

/// Column-parallel multi-row fused GEMM: output columns split into
/// nibble-aligned ranges, one worker per range computing a `[m, range]`
/// partial from zero; each output column is accumulated by exactly one
/// worker in row order, so the result is bitwise identical to the
/// scalar [`matmul_packed_cols`] path (given `y` starts zeroed, the
/// same contract every matvec/matmul call site already keeps).
fn matmul_packed_par(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    m: usize,
    y: &mut [f32],
    workers: usize,
) -> Result<()> {
    let n = dec.n();
    let ranges = col_ranges(n, workers);
    let parts = threads::par_map(ranges.clone(), workers, |(c0, c1)| {
        let w = c1 - c0;
        let mut part = vec![0.0f32; m * w];
        let mut scale_row = vec![0.0f32; w];
        matmul_packed_cols(dec, l, x, m, &mut part, c0, c1, &mut scale_row);
        part
    });
    for ((c0, c1), part) in ranges.into_iter().zip(parts) {
        let w = c1 - c0;
        for mi in 0..m {
            for (j, &v) in (c0..c1).zip(&part[mi * w..(mi + 1) * w]) {
                y[mi * n + j] += v;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::codec::{codec_for, rtn_decisions, FormatKind};
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    fn rand_x(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    /// reference: dense matvec over the dequantized tensor
    fn reference(w: &Tensor, l: usize, x: &[f32]) -> Vec<f32> {
        let (k, n) = (w.shape[w.rank() - 2], w.shape[w.rank() - 1]);
        let base = l * k * n;
        let mut y = vec![0.0f32; n];
        for row in 0..k {
            for col in 0..n {
                y[col] += x[row] * w.data[base + row * n + col];
            }
        }
        y
    }

    #[test]
    fn fused_matvec_matches_dequantized_dense() {
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let w = rand_w(&[2, 64, 32], 3, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let q = c.encode(&w, &p, &rtn_decisions(&p));
            let deq = q.dequantize().unwrap();
            let lin = Linear::from(q);
            assert!(lin.is_packed());
            assert_eq!((lin.k(), lin.n()), (64, 32));
            let x = rand_x(64, 7);
            let mut scratch = Vec::new();
            for l in 0..2 {
                let mut y = vec![0.0f32; 32];
                lin.matvec(l, &x, &mut y, &mut scratch, 1).unwrap();
                let expect = reference(&deq, l, &x);
                for (a, b) in y.iter().zip(&expect) {
                    assert!(
                        (a - b).abs() <= 1e-4 * b.abs().max(1e-3),
                        "{}: {a} vs {b}",
                        c.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dense_matvec_matches_reference() {
        let w = rand_w(&[3, 16, 8], 5, 0.2);
        let lin = Linear::Dense(w.clone());
        assert!(!lin.is_packed());
        assert_eq!(lin.payload_bytes(), 0);
        let x = rand_x(16, 9);
        let mut scratch = Vec::new();
        for l in 0..3 {
            let mut y = vec![0.0f32; 8];
            lin.matvec(l, &x, &mut y, &mut scratch, 1).unwrap();
            let expect = reference(&w, l, &x);
            for (a, b) in y.iter().zip(&expect) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-4), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_columns_bitwise_match_scalar() {
        // big enough to cross PAR_MACS with default workers; compare the
        // forced-parallel path against the forced-scalar path bit-for-bit
        let w = rand_w(&[1, 128, 64], 11, 0.1);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let q = c.encode(&w, &p, &rtn_decisions(&p));
        let dec = q.block_decode().unwrap();
        let x = rand_x(128, 13);
        let mut scalar = vec![0.0f32; 64];
        let mut scale_row = vec![0.0f32; 64];
        matvec_packed_cols(&dec, 0, &x, &mut scalar, 0, 64, &mut scale_row);
        let mut par = vec![0.0f32; 64];
        matvec_packed_par(&dec, 0, &x, &mut par, 4).unwrap();
        assert_eq!(scalar, par, "column-parallel result must be bitwise identical");

        // the public matvec path: above PAR_MACS, workers>1 takes the
        // parallel branch and must still match workers=1 bit-for-bit
        let w = rand_w(&[1, 512, 512], 12, 0.1);
        let p = c.prepare(&w);
        let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
        let x = rand_x(512, 17);
        let mut scratch = Vec::new();
        let mut a = vec![0.0f32; 512];
        lin.matvec(0, &x, &mut a, &mut scratch, 1).unwrap();
        let mut b = vec![0.0f32; 512];
        lin.matvec(0, &x, &mut b, &mut scratch, 4).unwrap();
        assert_eq!(a, b, "auto-parallel matvec diverged from scalar");
    }

    #[test]
    fn matmul_rows_bitwise_match_matvec_all_formats() {
        // the load-bearing tentpole invariant: every output row of the
        // multi-row fused GEMM is bitwise identical to the matvec of its
        // input row, for every format, M around and past the tile size
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let w = rand_w(&[2, 64, 32], 21, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
            for m in [1usize, 2, 7, 8, 9, 17] {
                let x = rand_x(m * 64, 100 + m as u64);
                let mut scratch = Vec::new();
                for l in 0..2 {
                    let mut ym = vec![0.0f32; m * 32];
                    lin.matmul(l, &x, m, &mut ym, &mut scratch, 1).unwrap();
                    for mi in 0..m {
                        let mut yv = vec![0.0f32; 32];
                        lin.matvec(l, &x[mi * 64..(mi + 1) * 64], &mut yv, &mut scratch, 1)
                            .unwrap();
                        assert_eq!(
                            &ym[mi * 32..(mi + 1) * 32],
                            &yv[..],
                            "{}: m={m} l={l} row {mi} diverged from matvec",
                            c.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_dense_rows_bitwise_match_matvec() {
        let w = rand_w(&[2, 16, 8], 23, 0.2);
        let lin = Linear::Dense(w);
        let m = 11;
        let x = rand_x(m * 16, 29);
        let mut scratch = Vec::new();
        let mut ym = vec![0.0f32; m * 8];
        lin.matmul(1, &x, m, &mut ym, &mut scratch, 1).unwrap();
        for mi in 0..m {
            let mut yv = vec![0.0f32; 8];
            lin.matvec(1, &x[mi * 16..(mi + 1) * 16], &mut yv, &mut scratch, 1).unwrap();
            assert_eq!(&ym[mi * 8..(mi + 1) * 8], &yv[..], "dense row {mi}");
        }
    }

    #[test]
    fn matmul_parallel_bitwise_matches_scalar() {
        // above PAR_MACS with workers > 1 the column-parallel branch
        // engages and must match the scalar branch bit-for-bit
        let w = rand_w(&[1, 256, 256], 31, 0.1);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
        let m = 12; // 12 * 256 * 256 MACs > PAR_MACS
        let x = rand_x(m * 256, 37);
        let mut scratch = Vec::new();
        let mut a = vec![0.0f32; m * 256];
        lin.matmul(0, &x, m, &mut a, &mut scratch, 1).unwrap();
        let mut b = vec![0.0f32; m * 256];
        lin.matmul(0, &x, m, &mut b, &mut scratch, 4).unwrap();
        assert_eq!(a, b, "column-parallel matmul diverged from scalar");
    }

    #[test]
    fn matmul_zero_rows_and_bad_shapes() {
        let w = rand_w(&[16, 8], 41, 0.1);
        let lin = Linear::Dense(w);
        let mut scratch = Vec::new();
        // m = 0 is a no-op
        let mut y0: Vec<f32> = vec![];
        lin.matmul(0, &[], 0, &mut y0, &mut scratch, 1).unwrap();
        // mismatched x / y lengths error
        let mut y = vec![0.0f32; 2 * 8];
        assert!(lin.matmul(0, &[0.0; 16], 2, &mut y, &mut scratch, 1).is_err());
        let mut short = vec![0.0f32; 8];
        assert!(lin.matmul(0, &[0.0; 32], 2, &mut short, &mut scratch, 1).is_err());
    }

    #[test]
    fn matvec_rejects_bad_shapes() {
        let w = rand_w(&[16, 8], 1, 0.1);
        let lin = Linear::Dense(w);
        let mut scratch = Vec::new();
        let mut y = vec![0.0f32; 8];
        assert!(lin.matvec(0, &[0.0; 4], &mut y, &mut scratch, 1).is_err());
        let mut short = vec![0.0f32; 4];
        assert!(lin.matvec(0, &[0.0; 16], &mut short, &mut scratch, 1).is_err());
    }
}
