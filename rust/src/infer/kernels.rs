//! Fused nibble-decode + matmul kernels over packed weights.
//!
//! The native backend's whole linear stack funnels through
//! [`Linear::matvec`]: `y += x @ W[l]` for one `[K] → [N]` layer slice,
//! where `W` stays in its 4-bit packed form and every element is decoded
//! *inside* the GEMM inner loop — two table lookups and a multiply per
//! weight, via [`BlockDecode`]. No dense f32 copy of a quantized layer
//! ever materializes on the serving path.
//!
//! Layout intuition: codes are packed row-major two-per-byte along the
//! output (`N`) axis, so the kernel walks `y += x[row] * W[row, :]`
//! row by row — each row is one contiguous byte run, each 16/32-row
//! block shares one decoded scale row. Per-element work:
//!
//! ```text
//! y[j] += xv * elem_lut[nibble] * scale_row[j]
//! ```
//!
//! When the caller allows it (decode at batch 1 — never nested under the
//! backend's per-slot fan-out), large matvecs split their output columns
//! across [`threads::par_map`] workers; every column is accumulated by
//! exactly one worker in row order, so parallel results are bitwise
//! identical to scalar results regardless of worker count.
//!
//! On top of the scalar LUT reference sit explicit SIMD paths
//! (DESIGN.md §12): [`decode_nibbles`] expands a run of packed bytes
//! into f32 elements with vector table lookups (AVX2
//! `vpermps`-as-pshufb on x86_64, `tbl` byte-plane lookups on aarch64),
//! and [`axpy_scaled`] vectorizes the `y += (x·e)·s` update with
//! separate mul/mul/add (never a fused multiply-add), so every SIMD
//! lane performs bit-for-bit the scalar op sequence. The path is picked
//! once per process by [`kernel_path`] — runtime feature detection with
//! a `FAAR_FORCE_SCALAR` env override that pins the bitwise reference.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::formats::codec::{BlockDecode, DecodeTables, QuantTensor};
use crate::tensor::Tensor;
use crate::util::threads;

/// MAC count above which a single matvec fans out across threads.
pub const PAR_MACS: usize = 1 << 18;

/// Register-block tile height for [`Linear::matmul`]: activation rows
/// processed per pass over the packed payload. Each packed byte is read
/// and LUT-decoded once per tile and applied to all `TILE_M` rows, so a
/// `[M, K]` batch touches the payload `ceil(M / TILE_M)` times instead
/// of `M` times. 16 (up from 8) so one block decode through
/// [`decode_nibbles`] feeds twice as many vector-accumulated rows.
pub const TILE_M: usize = 16;

/// Which nibble-decode implementation the process dispatched to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// AVX2 shuffle decode (x86_64, detected at runtime)
    Avx2,
    /// NEON `tbl` decode (aarch64, detected at runtime)
    Neon,
    /// portable scalar LUT loops — the bitwise reference
    Scalar,
}

impl KernelPath {
    /// Short lowercase name for logs and bench config blocks.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
            KernelPath::Scalar => "scalar",
        }
    }
}

/// The decode path this process uses, decided once and cached: the
/// `FAAR_FORCE_SCALAR` env override wins, then runtime CPU feature
/// detection, then the scalar fallback. Every SIMD path is bitwise
/// identical to scalar (property-tested), so the choice is performance
/// only — but the override keeps a pinnable reference arm for CI.
pub fn kernel_path() -> KernelPath {
    static PATH: OnceLock<KernelPath> = OnceLock::new();
    *PATH.get_or_init(detect_kernel_path)
}

fn detect_kernel_path() -> KernelPath {
    if std::env::var_os("FAAR_FORCE_SCALAR").is_some() {
        return KernelPath::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelPath::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelPath::Neon;
        }
    }
    KernelPath::Scalar
}

/// Comma-joined list of the decode-relevant CPU features this machine
/// reports, for the serve startup log and bench config blocks — so a
/// recorded perf number is attributable to a hardware capability set.
pub fn cpu_features() -> String {
    #[allow(unused_mut)]
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if std::arch::is_x86_feature_detected!("fma") {
            feats.push("fma");
        }
        if std::arch::is_x86_feature_detected!("sse4.1") {
            feats.push("sse4.1");
        }
        if std::arch::is_x86_feature_detected!("ssse3") {
            feats.push("ssse3");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            feats.push("neon");
        }
    }
    if feats.is_empty() {
        "none".to_string()
    } else {
        feats.join(",")
    }
}

/// Decode `2 * bytes.len()` packed nibbles (low nibble first) into f32
/// elements through the 16-entry `elem` LUT, using `path`'s vector
/// units. `out.len()` must equal `2 * bytes.len()`.
///
/// Every path produces **bitwise identical** output — the lookup is
/// exact, including the sign of the `-0.0` at code 8 — so callers pick
/// a path for speed, never for semantics. A SIMD path requested on
/// hardware that lacks it silently runs scalar (the feature re-check is
/// one cached-bitset test per call).
pub fn decode_nibbles(path: KernelPath, elem: &[f32; 16], bytes: &[u8], out: &mut [f32]) {
    assert_eq!(out.len(), 2 * bytes.len(), "decode_nibbles output length");
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 => {
            if std::arch::is_x86_feature_detected!("avx2") {
                // SAFETY: avx2 support just verified on this CPU
                unsafe { decode_nibbles_avx2(elem, bytes, out) }
            } else {
                decode_nibbles_scalar(elem, bytes, out);
            }
        }
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                // SAFETY: neon support just verified on this CPU
                unsafe { decode_nibbles_neon(elem, bytes, out) }
            } else {
                decode_nibbles_scalar(elem, bytes, out);
            }
        }
        _ => decode_nibbles_scalar(elem, bytes, out),
    }
}

/// The scalar reference decode: two LUT reads per byte.
fn decode_nibbles_scalar(elem: &[f32; 16], bytes: &[u8], out: &mut [f32]) {
    for (j2, &b) in bytes.iter().enumerate() {
        out[2 * j2] = elem[(b & 0x0F) as usize];
        out[2 * j2 + 1] = elem[(b >> 4) as usize];
    }
}

/// AVX2 shuffle decode: 16 packed bytes → 32 f32 elements per
/// iteration. Nibbles are split and interleaved back to column order
/// with byte unpacks, widened to i32 lanes, and looked up with two
/// `vpermps` gathers over the LUT halves blended on `code > 7` — the
/// 8-lane-f32 equivalent of a `pshufb` table lookup, reproducing the
/// LUT entries bit-for-bit (including the `-0.0` at code 8).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn decode_nibbles_avx2(elem: &[f32; 16], bytes: &[u8], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let tab_lo = _mm256_loadu_ps(elem.as_ptr()); // codes 0..8
    let tab_hi = _mm256_loadu_ps(elem.as_ptr().add(8)); // codes 8..16
    let seven = _mm256_set1_epi32(7);
    let nib_mask = _mm_set1_epi8(0x0F);
    let mut i = 0usize;
    while i + 16 <= bytes.len() {
        let b = _mm_loadu_si128(bytes.as_ptr().add(i) as *const __m128i);
        let lo = _mm_and_si128(b, nib_mask);
        let hi = _mm_and_si128(_mm_srli_epi16(b, 4), nib_mask);
        // interleave to element order: byte k holds elements 2k, 2k+1
        let codes = [_mm_unpacklo_epi8(lo, hi), _mm_unpackhi_epi8(lo, hi)];
        for (half, &idx16) in codes.iter().enumerate() {
            let quads = [
                _mm256_cvtepu8_epi32(idx16),
                _mm256_cvtepu8_epi32(_mm_srli_si128(idx16, 8)),
            ];
            for (quad, &idx) in quads.iter().enumerate() {
                let vlo = _mm256_permutevar8x32_ps(tab_lo, idx);
                let vhi = _mm256_permutevar8x32_ps(tab_hi, idx);
                let pick_hi = _mm256_castsi256_ps(_mm256_cmpgt_epi32(idx, seven));
                let v = _mm256_blendv_ps(vlo, vhi, pick_hi);
                _mm256_storeu_ps(out.as_mut_ptr().add(2 * i + 16 * half + 8 * quad), v);
            }
        }
        i += 16;
    }
    decode_nibbles_scalar(elem, &bytes[i..], &mut out[2 * i..]);
}

/// NEON decode: 16 packed bytes → 32 f32 elements per iteration via
/// four `tbl` lookups over the byte planes of the LUT (table p holds
/// byte p of each f32 entry), then zip the planes back into
/// little-endian f32s. Exact — the stored words are the LUT entries'
/// own bit patterns.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn decode_nibbles_neon(elem: &[f32; 16], bytes: &[u8], out: &mut [f32]) {
    use std::arch::aarch64::*;
    let mut planes = [[0u8; 16]; 4];
    for (c, e) in elem.iter().enumerate() {
        for (p, byte) in e.to_le_bytes().into_iter().enumerate() {
            planes[p][c] = byte;
        }
    }
    let t0 = vld1q_u8(planes[0].as_ptr());
    let t1 = vld1q_u8(planes[1].as_ptr());
    let t2 = vld1q_u8(planes[2].as_ptr());
    let t3 = vld1q_u8(planes[3].as_ptr());
    let nib_mask = vdupq_n_u8(0x0F);
    let mut i = 0usize;
    while i + 16 <= bytes.len() {
        let b = vld1q_u8(bytes.as_ptr().add(i));
        let lo = vandq_u8(b, nib_mask);
        let hi = vshrq_n_u8::<4>(b);
        let codes = [vzip1q_u8(lo, hi), vzip2q_u8(lo, hi)];
        for (half, &idx) in codes.iter().enumerate() {
            let b0 = vqtbl1q_u8(t0, idx);
            let b1 = vqtbl1q_u8(t1, idx);
            let b2 = vqtbl1q_u8(t2, idx);
            let b3 = vqtbl1q_u8(t3, idx);
            // zip byte planes into 16 little-endian f32 words
            let w01l = vreinterpretq_u16_u8(vzip1q_u8(b0, b1));
            let w01h = vreinterpretq_u16_u8(vzip2q_u8(b0, b1));
            let w23l = vreinterpretq_u16_u8(vzip1q_u8(b2, b3));
            let w23h = vreinterpretq_u16_u8(vzip2q_u8(b2, b3));
            let base = out.as_mut_ptr().add(2 * i + 16 * half);
            vst1q_f32(base, vreinterpretq_f32_u16(vzip1q_u16(w01l, w23l)));
            vst1q_f32(base.add(4), vreinterpretq_f32_u16(vzip2q_u16(w01l, w23l)));
            vst1q_f32(base.add(8), vreinterpretq_f32_u16(vzip1q_u16(w01h, w23h)));
            vst1q_f32(base.add(12), vreinterpretq_f32_u16(vzip2q_u16(w01h, w23h)));
        }
        i += 16;
    }
    decode_nibbles_scalar(elem, &bytes[i..], &mut out[2 * i..]);
}

/// `y[j] += (xv * e[j]) * s[j]` over equal-length slices, vectorized on
/// `path` with separate multiply/multiply/add — **never** a hardware
/// FMA, so each lane's rounding matches the scalar reference exactly
/// and every path stays bitwise identical.
pub fn axpy_scaled(path: KernelPath, xv: f32, e: &[f32], s: &[f32], y: &mut [f32]) {
    debug_assert!(e.len() == y.len() && s.len() == y.len(), "axpy_scaled lengths");
    match path {
        #[cfg(target_arch = "x86_64")]
        KernelPath::Avx2 => {
            if std::arch::is_x86_feature_detected!("avx2") {
                // SAFETY: avx2 support just verified on this CPU
                unsafe { axpy_scaled_avx2(xv, e, s, y) }
            } else {
                axpy_scaled_scalar(xv, e, s, y);
            }
        }
        #[cfg(target_arch = "aarch64")]
        KernelPath::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                // SAFETY: neon support just verified on this CPU
                unsafe { axpy_scaled_neon(xv, e, s, y) }
            } else {
                axpy_scaled_scalar(xv, e, s, y);
            }
        }
        _ => axpy_scaled_scalar(xv, e, s, y),
    }
}

fn axpy_scaled_scalar(xv: f32, e: &[f32], s: &[f32], y: &mut [f32]) {
    for (j, yj) in y.iter_mut().enumerate() {
        *yj += xv * e[j] * s[j];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_scaled_avx2(xv: f32, e: &[f32], s: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = y.len();
    let xvv = _mm256_set1_ps(xv);
    let mut j = 0usize;
    while j + 8 <= n {
        let ev = _mm256_loadu_ps(e.as_ptr().add(j));
        let sv = _mm256_loadu_ps(s.as_ptr().add(j));
        let yv = _mm256_loadu_ps(y.as_ptr().add(j));
        // (xv * e) * s, two roundings — bitwise the scalar op order
        let t = _mm256_mul_ps(_mm256_mul_ps(xvv, ev), sv);
        _mm256_storeu_ps(y.as_mut_ptr().add(j), _mm256_add_ps(yv, t));
        j += 8;
    }
    axpy_scaled_scalar(xv, &e[j..], &s[j..], &mut y[j..]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_scaled_neon(xv: f32, e: &[f32], s: &[f32], y: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = y.len();
    let xvv = vdupq_n_f32(xv);
    let mut j = 0usize;
    while j + 4 <= n {
        let ev = vld1q_f32(e.as_ptr().add(j));
        let sv = vld1q_f32(s.as_ptr().add(j));
        let yv = vld1q_f32(y.as_ptr().add(j));
        // vmul + vadd, not vfma: keep the scalar rounding sequence
        let t = vmulq_f32(vmulq_f32(xvv, ev), sv);
        vst1q_f32(y.as_mut_ptr().add(j), vaddq_f32(yv, t));
        j += 4;
    }
    axpy_scaled_scalar(xv, &e[j..], &s[j..], &mut y[j..]);
}

/// A packed layer stack plus its precomputed decode tables, so the GEMM
/// hot loop builds its [`BlockDecode`] view with a memcpy instead of
/// re-deriving 272 LUT entries per call.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    q: QuantTensor,
    tables: DecodeTables,
}

impl PackedLinear {
    /// Wrap a packed payload, precomputing its format's decode tables.
    pub fn new(q: QuantTensor) -> PackedLinear {
        let tables = q.format.decode_tables();
        PackedLinear { q, tables }
    }

    /// The packed payload.
    pub fn quant(&self) -> &QuantTensor {
        &self.q
    }
}

/// One weight stack (`[L, K, N]` or `[K, N]`) in whichever form it is
/// held: packed 4-bit (the quantized linears) or dense f32 (the
/// embedding/norm/head parameters and any non-quantized fallback).
#[derive(Clone, Debug)]
pub enum Linear {
    /// dense f32 weights
    Dense(Tensor),
    /// packed 4-bit payload, decoded on the fly inside the GEMM loop
    Packed(PackedLinear),
}

impl From<QuantTensor> for Linear {
    fn from(q: QuantTensor) -> Linear {
        Linear::Packed(PackedLinear::new(q))
    }
}

impl Linear {
    /// Contraction (input) dimension.
    pub fn k(&self) -> usize {
        let shape = self.shape();
        shape[shape.len() - 2]
    }

    /// Output dimension.
    pub fn n(&self) -> usize {
        let shape = self.shape();
        shape[shape.len() - 1]
    }

    /// The full weight shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            Linear::Dense(t) => &t.shape,
            Linear::Packed(p) => &p.q.shape,
        }
    }

    /// True when the layer is held packed.
    pub fn is_packed(&self) -> bool {
        matches!(self, Linear::Packed(_))
    }

    /// Packed payload bytes (0 for dense layers).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Linear::Dense(_) => 0,
            Linear::Packed(p) => p.q.payload_bytes(),
        }
    }

    /// `y += x @ W[l]` for slice `l`: `x` is `[K]`, `y` is `[N]`.
    ///
    /// `scratch` holds the decoded scale row between calls so the hot
    /// loop never allocates. `workers > 1` allows the column-parallel
    /// path for matvecs above [`PAR_MACS`]; callers already inside a
    /// batch fan-out pass 1 so thread pools never nest. Accumulation is
    /// plain f32 in row order — bitwise identical between the scalar and
    /// column-parallel paths.
    pub fn matvec(
        &self,
        l: usize,
        x: &[f32],
        y: &mut [f32],
        scratch: &mut Vec<f32>,
        workers: usize,
    ) -> Result<()> {
        let (k, n) = (self.k(), self.n());
        if x.len() != k || y.len() != n {
            bail!("matvec: x[{}] @ W[{k}, {n}] -> y[{}]", x.len(), y.len());
        }
        match self {
            Linear::Dense(t) => {
                let base = l * k * n;
                for (row, &xv) in x.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &t.data[base + row * n..base + (row + 1) * n];
                    for (yj, &w) in y.iter_mut().zip(wrow) {
                        *yj += xv * w;
                    }
                }
                Ok(())
            }
            Linear::Packed(p) => {
                let dec = p.q.block_decode_cached(&p.tables)?;
                let path = kernel_path();
                if workers > 1 && k * n >= PAR_MACS {
                    return matvec_packed_par(&dec, l, x, y, workers, path);
                }
                if path == KernelPath::Scalar {
                    scratch.resize(n, 0.0);
                    matvec_packed_cols(&dec, l, x, y, 0, n, scratch);
                } else {
                    // split one scratch allocation into the scale row and
                    // the decoded-element buffer the SIMD loop fills
                    scratch.resize(2 * n, 0.0);
                    let (scale_row, ebuf) = scratch.split_at_mut(n);
                    matvec_packed_cols_simd(&dec, l, x, y, 0, n, scale_row, ebuf, path);
                }
                Ok(())
            }
        }
    }

    /// Multi-row fused GEMM: `Y[M, N] += X[M, K] @ W[l]`, both row-major.
    ///
    /// The packed path tiles over M in blocks of [`TILE_M`]: each packed
    /// byte is read and nibble-decoded **once per tile** and applied to
    /// every activation row in the tile, and each block-scale row is
    /// decoded once per (block, tile) — where `M` calls to
    /// [`Self::matvec`] would stream and decode the whole payload `M`
    /// times. Accumulation stays column-in-row-order per output row with
    /// the exact op order of `matvec` (`(x * elem) * scale`, zero inputs
    /// skipped), so every output row is **bitwise identical** to the
    /// matvec of its input row — `M = 1` is a drop-in replacement.
    ///
    /// `scratch` and `workers` behave as in [`Self::matvec`]; the
    /// column-parallel split engages above [`PAR_MACS`] total MACs and
    /// each column is still accumulated by one worker in row order.
    pub fn matmul(
        &self,
        l: usize,
        x: &[f32],
        m: usize,
        y: &mut [f32],
        scratch: &mut Vec<f32>,
        workers: usize,
    ) -> Result<()> {
        let (k, n) = (self.k(), self.n());
        if x.len() != m * k || y.len() != m * n {
            bail!(
                "matmul: x[{}] @ W[{k}, {n}] -> y[{}] for m={m} rows",
                x.len(),
                y.len()
            );
        }
        if m == 0 {
            return Ok(());
        }
        match self {
            Linear::Dense(t) => {
                matmul_dense_rows(&t.data[l * k * n..(l + 1) * k * n], x, m, k, n, y);
                Ok(())
            }
            Linear::Packed(p) => {
                let dec = p.q.block_decode_cached(&p.tables)?;
                let path = kernel_path();
                if workers > 1 && m * k * n >= PAR_MACS {
                    return matmul_packed_par(&dec, l, x, m, y, workers, path);
                }
                if path == KernelPath::Scalar {
                    scratch.resize(n, 0.0);
                    matmul_packed_cols(&dec, l, x, m, y, 0, n, scratch);
                } else {
                    scratch.resize(2 * n, 0.0);
                    let (scale_row, ebuf) = scratch.split_at_mut(n);
                    matmul_packed_cols_simd(&dec, l, x, m, y, 0, n, scale_row, ebuf, path);
                }
                Ok(())
            }
        }
    }
}

/// Dense multi-row GEMM, tiled over M so each weight row is loaded once
/// per tile. Per output row the accumulation order and op order are
/// exactly the dense `matvec` path's (`y[j] += x * w`, rows in order,
/// zero inputs skipped), so rows match matvec bitwise.
fn matmul_dense_rows(w: &[f32], x: &[f32], m: usize, k: usize, n: usize, y: &mut [f32]) {
    let mut tile = 0;
    while tile < m {
        let tm = (m - tile).min(TILE_M);
        for row in 0..k {
            let mut xs = [0.0f32; TILE_M];
            let mut any = false;
            for (mi, xv) in xs.iter_mut().enumerate().take(tm) {
                *xv = x[(tile + mi) * k + row];
                any |= *xv != 0.0;
            }
            if !any {
                continue;
            }
            let wrow = &w[row * n..(row + 1) * n];
            for (j, &wv) in wrow.iter().enumerate() {
                for (mi, &xv) in xs.iter().enumerate().take(tm) {
                    if xv == 0.0 {
                        continue;
                    }
                    y[(tile + mi) * n + j] += xv * wv;
                }
            }
        }
        tile += TILE_M;
    }
}

/// The fused inner loop over an output-column range `[c0, c1)`:
/// `y[0..c1-c0] += x @ W[l, :, c0..c1]`, decoding nibbles and block
/// scales in place. `scale_row` is `c1 - c0` long — each worker decodes
/// only its own chunk's scales. `c0` and `c1` must be even (nibble pairs
/// share a byte).
fn matvec_packed_cols(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let block = dec.block();
    for kb in 0..dec.block_rows() {
        dec.scale_range_into(l, kb, c0, c1, scale_row);
        for r in 0..block {
            let row = kb * block + r;
            let xv = x[row];
            if xv == 0.0 {
                continue;
            }
            let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
            for (j2, &b) in bytes.iter().enumerate() {
                let j = 2 * j2;
                y[j] += xv * dec.elem(b & 0x0F) * scale_row[j];
                y[j + 1] += xv * dec.elem(b >> 4) * scale_row[j + 1];
            }
        }
    }
}

/// The vector variant of [`matvec_packed_cols`]: each non-zero input
/// row's packed bytes are expanded once into `ebuf` through
/// [`decode_nibbles`] and applied with one [`axpy_scaled`] sweep —
/// byte-at-a-time LUT calls become two wide vector passes per row.
/// Bitwise identical to the scalar loop: the decode is exact and the
/// axpy keeps the `(x·e)·s` then add rounding sequence per element.
fn matvec_packed_cols_simd(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
    ebuf: &mut [f32],
    path: KernelPath,
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let (block, w) = (dec.block(), c1 - c0);
    let elem = dec.elem_table();
    for kb in 0..dec.block_rows() {
        dec.scale_range_into(l, kb, c0, c1, &mut scale_row[..w]);
        for r in 0..block {
            let row = kb * block + r;
            let xv = x[row];
            if xv == 0.0 {
                continue;
            }
            let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
            decode_nibbles(path, elem, bytes, &mut ebuf[..w]);
            axpy_scaled(path, xv, &ebuf[..w], &scale_row[..w], &mut y[..w]);
        }
    }
}

/// Nibble-aligned output-column ranges for a `workers`-way split —
/// shared by the column-parallel matvec and matmul so the alignment
/// rule lives in exactly one place.
fn col_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let chunk = ((n.div_ceil(workers) + 1) & !1).max(2);
    (0..n).step_by(chunk).map(|c0| (c0, (c0 + chunk).min(n))).collect()
}

/// Column-parallel fused matvec: output columns are split into
/// nibble-aligned ranges, one worker per range; each column is still
/// accumulated sequentially in row order, so the result is bitwise
/// identical to the scalar path.
fn matvec_packed_par(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    y: &mut [f32],
    workers: usize,
    path: KernelPath,
) -> Result<()> {
    let ranges = col_ranges(dec.n(), workers);
    let parts = threads::par_map(ranges.clone(), workers, |(c0, c1)| {
        let w = c1 - c0;
        let mut part = vec![0.0f32; w];
        let mut scale_row = vec![0.0f32; w];
        if path == KernelPath::Scalar {
            matvec_packed_cols(dec, l, x, &mut part, c0, c1, &mut scale_row);
        } else {
            let mut ebuf = vec![0.0f32; w];
            matvec_packed_cols_simd(dec, l, x, &mut part, c0, c1, &mut scale_row, &mut ebuf, path);
        }
        part
    });
    for ((c0, c1), part) in ranges.into_iter().zip(parts) {
        for (j, v) in (c0..c1).zip(part) {
            y[j] += v;
        }
    }
    Ok(())
}

/// The multi-row fused inner loop over an output-column range `[c0, c1)`:
/// `y[mi, 0..c1-c0] += x[mi, :] @ W[l, :, c0..c1]` for all `m` rows,
/// with `y` laid out `[m, c1 - c0]` row-major. M is tiled in blocks of
/// [`TILE_M`]; within a tile each packed byte is loaded and
/// nibble-decoded once, each scale row once per (block, tile), and the
/// decoded values applied to every tile row. Per output row the element
/// op order matches [`matvec_packed_cols`] exactly. `c0`/`c1` must be
/// even (nibble pairs share a byte).
fn matmul_packed_cols(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    m: usize,
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let (block, k, w) = (dec.block(), dec.k(), c1 - c0);
    let mut tile = 0;
    while tile < m {
        let tm = (m - tile).min(TILE_M);
        for kb in 0..dec.block_rows() {
            // one scale-row decode per (block, tile) — amortized over
            // every row and every payload byte of the block
            dec.scale_range_into(l, kb, c0, c1, scale_row);
            for r in 0..block {
                let row = kb * block + r;
                // gather the tile's activation column for this K row
                let mut xs = [0.0f32; TILE_M];
                let mut any = false;
                for (mi, xv) in xs.iter_mut().enumerate().take(tm) {
                    *xv = x[(tile + mi) * k + row];
                    any |= *xv != 0.0;
                }
                if !any {
                    continue;
                }
                let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
                for (j2, &b) in bytes.iter().enumerate() {
                    let j = 2 * j2;
                    // one byte load + two LUT decodes, applied to all
                    // tm rows (matvec pays these per row)
                    let e0 = dec.elem(b & 0x0F);
                    let e1 = dec.elem(b >> 4);
                    let s0 = scale_row[j];
                    let s1 = scale_row[j + 1];
                    for (mi, &xv) in xs.iter().enumerate().take(tm) {
                        if xv == 0.0 {
                            continue;
                        }
                        let yo = (tile + mi) * w + j;
                        y[yo] += xv * e0 * s0;
                        y[yo + 1] += xv * e1 * s1;
                    }
                }
            }
        }
        tile += TILE_M;
    }
}

/// The vector variant of [`matmul_packed_cols`]: within a tile each
/// packed byte run is expanded **once** into `ebuf` through
/// [`decode_nibbles`] and swept across every non-zero tile row with
/// [`axpy_scaled`] — the decode cost is amortized over [`TILE_M`] rows
/// and the per-row update runs at vector width. Per output row the
/// element op order still matches [`matvec_packed_cols`] exactly (each
/// `y[mi, j]` receives one `(x·e)·s` add per K row, in row order), so
/// rows stay bitwise identical to matvec and to the scalar tile loop.
fn matmul_packed_cols_simd(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    m: usize,
    y: &mut [f32],
    c0: usize,
    c1: usize,
    scale_row: &mut [f32],
    ebuf: &mut [f32],
    path: KernelPath,
) {
    debug_assert!(c0 % 2 == 0 && c1 % 2 == 0, "column range must be nibble-aligned");
    let (block, k, w) = (dec.block(), dec.k(), c1 - c0);
    let elem = dec.elem_table();
    let mut tile = 0;
    while tile < m {
        let tm = (m - tile).min(TILE_M);
        for kb in 0..dec.block_rows() {
            dec.scale_range_into(l, kb, c0, c1, &mut scale_row[..w]);
            for r in 0..block {
                let row = kb * block + r;
                let mut xs = [0.0f32; TILE_M];
                let mut any = false;
                for (mi, xv) in xs.iter_mut().enumerate().take(tm) {
                    *xv = x[(tile + mi) * k + row];
                    any |= *xv != 0.0;
                }
                if !any {
                    continue;
                }
                let bytes = &dec.code_row(l, row)[c0 / 2..c1 / 2];
                // one decode per (row, tile), amortized over tm rows
                decode_nibbles(path, elem, bytes, &mut ebuf[..w]);
                for (mi, &xv) in xs.iter().enumerate().take(tm) {
                    if xv == 0.0 {
                        continue;
                    }
                    let yo = (tile + mi) * w;
                    axpy_scaled(path, xv, &ebuf[..w], &scale_row[..w], &mut y[yo..yo + w]);
                }
            }
        }
        tile += TILE_M;
    }
}

/// Column-parallel multi-row fused GEMM: output columns split into
/// nibble-aligned ranges, one worker per range computing a `[m, range]`
/// partial from zero; each output column is accumulated by exactly one
/// worker in row order, so the result is bitwise identical to the
/// scalar [`matmul_packed_cols`] path (given `y` starts zeroed, the
/// same contract every matvec/matmul call site already keeps).
fn matmul_packed_par(
    dec: &BlockDecode<'_>,
    l: usize,
    x: &[f32],
    m: usize,
    y: &mut [f32],
    workers: usize,
    path: KernelPath,
) -> Result<()> {
    let n = dec.n();
    let ranges = col_ranges(n, workers);
    let parts = threads::par_map(ranges.clone(), workers, |(c0, c1)| {
        let w = c1 - c0;
        let mut part = vec![0.0f32; m * w];
        let mut scale_row = vec![0.0f32; w];
        if path == KernelPath::Scalar {
            matmul_packed_cols(dec, l, x, m, &mut part, c0, c1, &mut scale_row);
        } else {
            let mut ebuf = vec![0.0f32; w];
            matmul_packed_cols_simd(
                dec, l, x, m, &mut part, c0, c1, &mut scale_row, &mut ebuf, path,
            );
        }
        part
    });
    for ((c0, c1), part) in ranges.into_iter().zip(parts) {
        let w = c1 - c0;
        for mi in 0..m {
            for (j, &v) in (c0..c1).zip(&part[mi * w..(mi + 1) * w]) {
                y[mi * n + j] += v;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::codec::{codec_for, rtn_decisions, FormatKind};
    use crate::util::rng::Rng;

    fn rand_w(shape: &[usize], seed: u64, std: f32) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 0.0, std);
        t
    }

    fn rand_x(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    /// reference: dense matvec over the dequantized tensor
    fn reference(w: &Tensor, l: usize, x: &[f32]) -> Vec<f32> {
        let (k, n) = (w.shape[w.rank() - 2], w.shape[w.rank() - 1]);
        let base = l * k * n;
        let mut y = vec![0.0f32; n];
        for row in 0..k {
            for col in 0..n {
                y[col] += x[row] * w.data[base + row * n + col];
            }
        }
        y
    }

    #[test]
    fn fused_matvec_matches_dequantized_dense() {
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let w = rand_w(&[2, 64, 32], 3, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let q = c.encode(&w, &p, &rtn_decisions(&p));
            let deq = q.dequantize().unwrap();
            let lin = Linear::from(q);
            assert!(lin.is_packed());
            assert_eq!((lin.k(), lin.n()), (64, 32));
            let x = rand_x(64, 7);
            let mut scratch = Vec::new();
            for l in 0..2 {
                let mut y = vec![0.0f32; 32];
                lin.matvec(l, &x, &mut y, &mut scratch, 1).unwrap();
                let expect = reference(&deq, l, &x);
                for (a, b) in y.iter().zip(&expect) {
                    assert!(
                        (a - b).abs() <= 1e-4 * b.abs().max(1e-3),
                        "{}: {a} vs {b}",
                        c.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dense_matvec_matches_reference() {
        let w = rand_w(&[3, 16, 8], 5, 0.2);
        let lin = Linear::Dense(w.clone());
        assert!(!lin.is_packed());
        assert_eq!(lin.payload_bytes(), 0);
        let x = rand_x(16, 9);
        let mut scratch = Vec::new();
        for l in 0..3 {
            let mut y = vec![0.0f32; 8];
            lin.matvec(l, &x, &mut y, &mut scratch, 1).unwrap();
            let expect = reference(&w, l, &x);
            for (a, b) in y.iter().zip(&expect) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-4), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parallel_columns_bitwise_match_scalar() {
        // big enough to cross PAR_MACS with default workers; compare the
        // forced-parallel path against the forced-scalar path bit-for-bit
        let w = rand_w(&[1, 128, 64], 11, 0.1);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let q = c.encode(&w, &p, &rtn_decisions(&p));
        let dec = q.block_decode().unwrap();
        let x = rand_x(128, 13);
        let mut scalar = vec![0.0f32; 64];
        let mut scale_row = vec![0.0f32; 64];
        matvec_packed_cols(&dec, 0, &x, &mut scalar, 0, 64, &mut scale_row);
        for path in [KernelPath::Scalar, kernel_path()] {
            let mut par = vec![0.0f32; 64];
            matvec_packed_par(&dec, 0, &x, &mut par, 4, path).unwrap();
            assert_eq!(
                scalar, par,
                "column-parallel ({}) result must be bitwise identical",
                path.name()
            );
        }

        // the public matvec path: above PAR_MACS, workers>1 takes the
        // parallel branch and must still match workers=1 bit-for-bit
        let w = rand_w(&[1, 512, 512], 12, 0.1);
        let p = c.prepare(&w);
        let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
        let x = rand_x(512, 17);
        let mut scratch = Vec::new();
        let mut a = vec![0.0f32; 512];
        lin.matvec(0, &x, &mut a, &mut scratch, 1).unwrap();
        let mut b = vec![0.0f32; 512];
        lin.matvec(0, &x, &mut b, &mut scratch, 4).unwrap();
        assert_eq!(a, b, "auto-parallel matvec diverged from scalar");
    }

    #[test]
    fn matmul_rows_bitwise_match_matvec_all_formats() {
        // the load-bearing tentpole invariant: every output row of the
        // multi-row fused GEMM is bitwise identical to the matvec of its
        // input row, for every format, M around and past the tile size
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let w = rand_w(&[2, 64, 32], 21, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
            for m in [1usize, 2, 7, 8, 9, 17] {
                let x = rand_x(m * 64, 100 + m as u64);
                let mut scratch = Vec::new();
                for l in 0..2 {
                    let mut ym = vec![0.0f32; m * 32];
                    lin.matmul(l, &x, m, &mut ym, &mut scratch, 1).unwrap();
                    for mi in 0..m {
                        let mut yv = vec![0.0f32; 32];
                        lin.matvec(l, &x[mi * 64..(mi + 1) * 64], &mut yv, &mut scratch, 1)
                            .unwrap();
                        assert_eq!(
                            &ym[mi * 32..(mi + 1) * 32],
                            &yv[..],
                            "{}: m={m} l={l} row {mi} diverged from matvec",
                            c.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_dense_rows_bitwise_match_matvec() {
        let w = rand_w(&[2, 16, 8], 23, 0.2);
        let lin = Linear::Dense(w);
        let m = 11;
        let x = rand_x(m * 16, 29);
        let mut scratch = Vec::new();
        let mut ym = vec![0.0f32; m * 8];
        lin.matmul(1, &x, m, &mut ym, &mut scratch, 1).unwrap();
        for mi in 0..m {
            let mut yv = vec![0.0f32; 8];
            lin.matvec(1, &x[mi * 16..(mi + 1) * 16], &mut yv, &mut scratch, 1).unwrap();
            assert_eq!(&ym[mi * 8..(mi + 1) * 8], &yv[..], "dense row {mi}");
        }
    }

    #[test]
    fn matmul_parallel_bitwise_matches_scalar() {
        // above PAR_MACS with workers > 1 the column-parallel branch
        // engages and must match the scalar branch bit-for-bit
        let w = rand_w(&[1, 256, 256], 31, 0.1);
        let c = codec_for(FormatKind::Nvfp4);
        let p = c.prepare(&w);
        let lin = Linear::from(c.encode(&w, &p, &rtn_decisions(&p)));
        let m = 12; // 12 * 256 * 256 MACs > PAR_MACS
        let x = rand_x(m * 256, 37);
        let mut scratch = Vec::new();
        let mut a = vec![0.0f32; m * 256];
        lin.matmul(0, &x, m, &mut a, &mut scratch, 1).unwrap();
        let mut b = vec![0.0f32; m * 256];
        lin.matmul(0, &x, m, &mut b, &mut scratch, 4).unwrap();
        assert_eq!(a, b, "column-parallel matmul diverged from scalar");
    }

    #[test]
    fn matmul_zero_rows_and_bad_shapes() {
        let w = rand_w(&[16, 8], 41, 0.1);
        let lin = Linear::Dense(w);
        let mut scratch = Vec::new();
        // m = 0 is a no-op
        let mut y0: Vec<f32> = vec![];
        lin.matmul(0, &[], 0, &mut y0, &mut scratch, 1).unwrap();
        // mismatched x / y lengths error
        let mut y = vec![0.0f32; 2 * 8];
        assert!(lin.matmul(0, &[0.0; 16], 2, &mut y, &mut scratch, 1).is_err());
        let mut short = vec![0.0f32; 8];
        assert!(lin.matmul(0, &[0.0; 32], 2, &mut short, &mut scratch, 1).is_err());
    }

    #[test]
    fn kernel_path_reports_and_features_stringify() {
        // the cached dispatch decision is stable across calls and maps
        // to a known name; the feature list is non-empty prose either way
        let p = kernel_path();
        assert_eq!(p, kernel_path());
        assert!(["avx2", "neon", "scalar"].contains(&p.name()));
        assert!(!cpu_features().is_empty());
    }

    #[test]
    fn decode_nibbles_simd_bitwise_matches_scalar() {
        // every format's elem LUT, every byte value, and ragged lengths
        // that exercise both the vector body and the scalar tail
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let tables = kind.decode_tables();
            let w = rand_w(&[16, 16], 51, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let q = c.encode(&w, &p, &rtn_decisions(&p));
            let dec = q.block_decode_cached(&tables).unwrap();
            let elem = dec.elem_table();
            for len in [0usize, 1, 7, 8, 15, 16, 17, 31, 32, 33, 64] {
                let bytes: Vec<u8> = (0..len).map(|i| (i * 37 + 11) as u8).collect();
                let mut a = vec![9.0f32; 2 * len];
                let mut b = vec![-9.0f32; 2 * len];
                decode_nibbles(KernelPath::Scalar, elem, &bytes, &mut a);
                decode_nibbles(kernel_path(), elem, &bytes, &mut b);
                // compare bit patterns: code 8 decodes to -0.0, which
                // == 0.0 would not catch
                let abits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bbits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(abits, bbits, "{}: len={len} decode diverged", kind.name());
            }
        }
    }

    #[test]
    fn axpy_scaled_simd_bitwise_matches_scalar() {
        for n in [0usize, 1, 3, 8, 9, 16, 31, 33] {
            let e = rand_x(n, 61);
            let s = rand_x(n, 62);
            let mut a = rand_x(n, 63);
            let mut b = a.clone();
            axpy_scaled(KernelPath::Scalar, 0.7, &e, &s, &mut a);
            axpy_scaled(kernel_path(), 0.7, &e, &s, &mut b);
            assert_eq!(a, b, "axpy diverged at n={n}");
        }
    }

    #[test]
    fn simd_cols_bitwise_match_scalar_cols() {
        // the full fused loops, scalar vs SIMD, on odd column counts
        // (34 columns: vector body + ragged tail) and partial ranges
        for kind in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let w = rand_w(&[2, 64, 34], 71, 0.1);
            let c = codec_for(kind);
            let p = c.prepare(&w);
            let q = c.encode(&w, &p, &rtn_decisions(&p));
            let dec = q.block_decode().unwrap();
            let path = kernel_path();
            for (c0, c1) in [(0usize, 34usize), (2, 18), (16, 34)] {
                let w_ = c1 - c0;
                let x = rand_x(64, 73);
                let mut ys = vec![0.0f32; w_];
                let mut scale = vec![0.0f32; w_];
                matvec_packed_cols(&dec, 1, &x, &mut ys, c0, c1, &mut scale);
                let mut yv = vec![0.0f32; w_];
                let mut ebuf = vec![0.0f32; w_];
                matvec_packed_cols_simd(&dec, 1, &x, &mut yv, c0, c1, &mut scale, &mut ebuf, path);
                assert_eq!(ys, yv, "{}: matvec cols [{c0},{c1}) diverged", kind.name());

                let m = TILE_M + 3;
                let xm = rand_x(m * 64, 79);
                let mut ms = vec![0.0f32; m * w_];
                matmul_packed_cols(&dec, 1, &xm, m, &mut ms, c0, c1, &mut scale);
                let mut mv = vec![0.0f32; m * w_];
                matmul_packed_cols_simd(
                    &dec, 1, &xm, m, &mut mv, c0, c1, &mut scale, &mut ebuf, path,
                );
                assert_eq!(ms, mv, "{}: matmul cols [{c0},{c1}) diverged", kind.name());
            }
        }
    }

    #[test]
    fn matvec_rejects_bad_shapes() {
        let w = rand_w(&[16, 8], 1, 0.1);
        let lin = Linear::Dense(w);
        let mut scratch = Vec::new();
        let mut y = vec![0.0f32; 8];
        assert!(lin.matvec(0, &[0.0; 4], &mut y, &mut scratch, 1).is_err());
        let mut short = vec![0.0f32; 4];
        assert!(lin.matvec(0, &[0.0; 16], &mut short, &mut scratch, 1).is_err());
    }
}
