//! Scalar transformer ops for the native backend, mirroring the JAX
//! graphs in `python/compile/model.py` op-for-op:
//!
//! * [`rmsnorm_into`] — `x * rsqrt(mean(x²) + 1e-5) * g`
//! * [`rope_tables`] / [`rope_inplace`] — rotate-half RoPE with
//!   `inv_freq = 10000^(-2i/hd)` per head
//! * [`softmax_inplace`] — max-subtracted softmax
//! * [`silu`] — `x * sigmoid(x)` (the SwiGLU gate)
//! * [`act_fake_quant`] — dynamic NVFP4 activation fake-quant
//!   (`ref.rtn_fake_quant_act`), computed **per token** — see the module
//!   note below
//!
//! ### Per-token activation scales
//!
//! The AOT graphs compute the activation global scale over the whole
//! `[B, T, F]` tensor (a graph-mode artifact: padding rows past `pos`
//! leak into the scale-of-scales). Incremental decode sees one token at
//! a time, so the native backend computes the two-level scale over the
//! single `[F]` vector instead — the deployable per-token recipe. The
//! difference only enters through E4M3 rounding of the block scales,
//! which is why native-vs-XLA parity is a documented tolerance rather
//! than bit identity (DESIGN.md §9), while native cached-vs-uncached
//! decode stays bit-exact.

use crate::formats::{e2m1, e4m3};

/// RMSNorm epsilon shared with `model.rmsnorm` (1e-5).
pub const RMS_EPS: f32 = 1e-5;

/// `out = x * rsqrt(mean(x²) + eps) * g`, elementwise over one token.
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), g.len());
    debug_assert_eq!(x.len(), out.len());
    let n = x.len().max(1);
    let mean_sq = x.iter().map(|&v| v * v).sum::<f32>() / n as f32;
    let r = 1.0 / (mean_sq + RMS_EPS).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * r * g[i];
    }
}

/// Precompute RoPE tables for `seq_len` positions of one head:
/// `cos[t * hd/2 + i] = cos(t * 10000^(-2i/hd))`, likewise `sin`.
/// Matches `model.rope_tables`.
pub fn rope_tables(seq_len: usize, head_dim: usize) -> (Vec<f32>, Vec<f32>) {
    let half = head_dim / 2;
    let mut cos = Vec::with_capacity(seq_len * half);
    let mut sin = Vec::with_capacity(seq_len * half);
    for t in 0..seq_len {
        for i in 0..half {
            // inv_freq = 1 / 10000^(2i / hd), computed in f32 like jnp
            let inv = 1.0f32 / 10000.0f32.powf((2 * i) as f32 / head_dim as f32);
            let f = t as f32 * inv;
            cos.push(f.cos());
            sin.push(f.sin());
        }
    }
    (cos, sin)
}

/// Apply rotate-half RoPE in place to one token's `[n_heads * head_dim]`
/// vector, using the position-`idx` rows of the precomputed tables.
/// Matches `model.apply_rope` (first/second half of each head rotate as
/// a pair).
pub fn rope_inplace(
    x: &mut [f32],
    n_heads: usize,
    head_dim: usize,
    cos: &[f32],
    sin: &[f32],
    idx: usize,
) {
    let half = head_dim / 2;
    debug_assert_eq!(x.len(), n_heads * head_dim);
    let c = &cos[idx * half..(idx + 1) * half];
    let s = &sin[idx * half..(idx + 1) * half];
    for h in 0..n_heads {
        let head = &mut x[h * head_dim..(h + 1) * head_dim];
        for i in 0..half {
            let x1 = head[i];
            let x2 = head[half + i];
            head[i] = x1 * c[i] - x2 * s[i];
            head[half + i] = x1 * s[i] + x2 * c[i];
        }
    }
}

/// Max-subtracted softmax in place (all entries finite on the decode
/// path — no causal mask is needed because the cache only holds
/// positions `<=` the query).
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in x.iter_mut() {
            *v /= sum;
        }
    }
}

/// SwiGLU gate nonlinearity: `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Dot product of two equal-length vectors (f32 accumulation, like the
/// XLA einsum on the CPU backend).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Dynamic NVFP4 activation fake-quant over one token's feature vector,
/// in place: blocks of 16 along the feature axis, E4M3 block scales over
/// a per-token fp32 global scale, E2M1 elements with RTN (ties toward
/// the lower node) — `ref.rtn_fake_quant_act` restricted to one token.
///
/// `x.len()` must be a multiple of 16 (guaranteed for every quantized
/// linear input: `d_model` and `mlp_hidden` are validated multiples of
/// the block size).
pub fn act_fake_quant(x: &mut [f32]) {
    const BLOCK: usize = 16;
    debug_assert_eq!(x.len() % BLOCK, 0, "activation dim must tile the block size");
    let amax_tot = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let s_global = (amax_tot / (e2m1::FP4_MAX * e4m3::E4M3_MAX)).max(1e-30);
    for block in x.chunks_mut(BLOCK) {
        let amax_blk = block.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s_eff = e4m3::roundtrip(amax_blk / e2m1::FP4_MAX / s_global) * s_global;
        if s_eff <= 0.0 {
            block.fill(0.0);
            continue;
        }
        for v in block.iter_mut() {
            let wt = (v.abs() / s_eff.max(1e-30)).min(e2m1::FP4_MAX);
            let signed = if *v < 0.0 { -wt } else { wt };
            *v = e2m1::decode(e2m1::encode_rtn(signed)) * s_eff;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_gain() {
        let x = vec![3.0f32, -3.0, 3.0, -3.0];
        let g = vec![1.0f32; 4];
        let mut out = vec![0.0f32; 4];
        rmsnorm_into(&x, &g, &mut out);
        // mean square is 9 → rsqrt ≈ 1/3
        for (o, v) in out.iter().zip(&x) {
            assert!((o - v / 3.0).abs() < 1e-3, "{o} vs {v}");
        }
        // gain vector scales per element
        let g2 = vec![2.0f32, 1.0, 0.5, 0.0];
        rmsnorm_into(&x, &g2, &mut out);
        assert_eq!(out[3], 0.0);
        assert!((out[0] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let (cos, sin) = rope_tables(4, 8);
        let orig: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut x = orig.clone();
        rope_inplace(&mut x, 2, 8, &cos, &sin, 0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
        // nonzero positions rotate (norm preserved per pair)
        let mut y = orig.clone();
        rope_inplace(&mut y, 2, 8, &cos, &sin, 3);
        assert_ne!(y, orig);
        for h in 0..2 {
            for i in 0..4 {
                let (a1, a2) = (orig[h * 8 + i], orig[h * 8 + 4 + i]);
                let (b1, b2) = (y[h * 8 + i], y[h * 8 + 4 + i]);
                let na = a1 * a1 + a2 * a2;
                let nb = b1 * b1 + b2 * b2;
                assert!((na - nb).abs() < 1e-3 * na.max(1.0), "{na} vs {nb}");
            }
        }
    }

    #[test]
    fn softmax_normalizes_and_orders() {
        let mut x = vec![1.0f32, 2.0, 3.0];
        softmax_inplace(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
        // large magnitudes stay finite (max subtraction)
        let mut y = vec![1000.0f32, 999.0];
        softmax_inplace(&mut y);
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(y[0] > y[1]);
    }

    #[test]
    fn silu_shape() {
        assert_eq!(silu(0.0), 0.0);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0) > -1e-3 && silu(-10.0) < 0.0);
    }

    #[test]
    fn act_quant_bounded_error_and_signs() {
        let mut x: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) / 5.0).collect();
        let orig = x.clone();
        act_fake_quant(&mut x);
        for (q, o) in x.iter().zip(&orig) {
            // worst-case half-gap at the top of the grid ≈ amax/6 ≈ 0.53,
            // plus E4M3 scale rounding slack
            assert!((q - o).abs() <= 0.6, "{q} vs {o}");
            // sign is preserved (magnitude-only quantization)
            assert!(q * o >= 0.0, "sign flip: {q} vs {o}");
        }
        // deterministic: same input, same output
        let mut again = orig.clone();
        act_fake_quant(&mut again);
        assert_eq!(again, x);
        // all-zero token stays zero
        let mut z = vec![0.0f32; 16];
        act_fake_quant(&mut z);
        assert!(z.iter().all(|&v| v == 0.0));
    }
}
