//! Paged key/value cache for the native inference backend.
//!
//! The cache turns batched greedy decode from O(T²) per emitted token
//! (recompute attention over the whole window every step) into O(T): a
//! slot's keys and values are computed once, stored, and only the newest
//! token runs through the linear stack each step.
//!
//! Two pieces:
//!
//! * [`KvPool`] — the shared page budget. Pages are fixed-size boxed
//!   float buffers; freed pages go to a free list and are handed back out
//!   before anything new is allocated, so steady-state serving does no
//!   allocation. `take` fails once `max_pages` buffers are outstanding —
//!   callers (the native backend) fall back to uncached compute rather
//!   than grow without bound.
//! * [`KvSeq`] — one slot's cache: a queue of pages it exclusively owns,
//!   holding `[n_layers, 2, d_model]` floats per cached token (keys are
//!   stored *post-RoPE*, values raw). Because each sequence owns its
//!   pages outright, a batch of slots can be processed fully in parallel
//!   with no locking on the hot path; the pool mutex is touched only at
//!   page-boundary alloc/free.
//!
//! Slot lifecycle (allocate on admit, free on completion/disconnect) is
//! driven by the scheduler through `StepBackend::release` — see
//! `serve::scheduler` and [`super::NativeBackend`].

use std::collections::VecDeque;

use anyhow::Result;

/// Typed error returned by [`KvPool::take`] when the page budget is
/// spent. The native backend downcasts to this (`downcast_ref`, which
/// survives any `context` wrapping) to pick the uncached-compute
/// fallback instead of failing the request.
#[derive(Clone, Copy, Debug)]
pub struct KvExhausted {
    /// pages outstanding when the take failed
    pub outstanding: usize,
}

impl std::fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv pool exhausted ({} pages outstanding)", self.outstanding)
    }
}

impl std::error::Error for KvExhausted {}

/// Geometry of one cached token slot: how many floats a token occupies
/// and how tokens tile into pages.
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// decoder layers
    pub n_layers: usize,
    /// model width (keys and values are `[d_model]` each per layer)
    pub d_model: usize,
    /// cached tokens per page
    pub page_tokens: usize,
}

impl KvLayout {
    /// Floats one cached token occupies (`n_layers * 2 * d_model`).
    pub fn token_floats(&self) -> usize {
        self.n_layers * 2 * self.d_model
    }

    /// Floats per page.
    pub fn page_floats(&self) -> usize {
        self.page_tokens * self.token_floats()
    }
}

/// Bounded page allocator shared by every slot of a native backend.
///
/// Freed pages are recycled (LIFO) before new ones are allocated, and the
/// total outstanding count never exceeds `max_pages`.
#[derive(Debug)]
pub struct KvPool {
    page_floats: usize,
    max_pages: usize,
    outstanding: usize,
    free: Vec<Box<[f32]>>,
}

impl KvPool {
    /// A pool handing out pages of `page_floats` floats, at most
    /// `max_pages` outstanding at once.
    pub fn new(page_floats: usize, max_pages: usize) -> KvPool {
        KvPool { page_floats, max_pages, outstanding: 0, free: Vec::new() }
    }

    /// An effectively unbounded pool (scratch compute, tests).
    pub fn unbounded(page_floats: usize) -> KvPool {
        KvPool::new(page_floats, usize::MAX)
    }

    /// Take one page, reusing a freed buffer when available. Errors once
    /// the outstanding count reaches the pool cap.
    pub fn take(&mut self) -> Result<Box<[f32]>> {
        if let Some(mut page) = self.free.pop() {
            page.fill(0.0);
            self.outstanding += 1;
            return Ok(page);
        }
        if self.outstanding >= self.max_pages {
            return Err(anyhow::Error::new(KvExhausted { outstanding: self.outstanding }));
        }
        self.outstanding += 1;
        Ok(vec![0.0f32; self.page_floats].into_boxed_slice())
    }

    /// Return a page to the free list.
    pub fn put(&mut self, page: Box<[f32]>) {
        debug_assert_eq!(page.len(), self.page_floats, "foreign page returned");
        debug_assert!(self.outstanding > 0, "put without matching take");
        self.outstanding = self.outstanding.saturating_sub(1);
        self.free.push(page);
    }

    /// Pages currently held by sequences (not in the free list).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Recycled pages waiting to be reused.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// The outstanding-page cap.
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }
}

/// One slot's cached keys/values: an append-only queue of owned pages.
///
/// Token `t`'s layer-`l` entries live at a fixed offset for the slot's
/// lifetime, so references handed out by [`Self::k`]/[`Self::v`] stay
/// valid across appends (pages are never moved, only pushed). The
/// sequence must be drained back into its pool with [`Self::clear`]
/// before it is dropped — the backend does this in `release`.
#[derive(Debug)]
pub struct KvSeq {
    layout: KvLayout,
    pages: VecDeque<Box<[f32]>>,
    len: usize,
}

impl KvSeq {
    /// An empty sequence for `layout`.
    pub fn new(layout: KvLayout) -> KvSeq {
        KvSeq { layout, pages: VecDeque::new(), len: 0 }
    }

    /// Cached tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tokens are cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Append one token slot (zero-initialized), taking a new page from
    /// `pool` when the tail page is full. On pool exhaustion the sequence
    /// is left unchanged and the caller decides the fallback.
    pub fn push(&mut self, pool: &mut KvPool) -> Result<()> {
        if self.len % self.layout.page_tokens == 0 {
            self.pages.push_back(pool.take()?);
        }
        self.len += 1;
        Ok(())
    }

    /// Reserve `extra` token slots (zero-initialized) in one pool
    /// transaction — the bulk form of [`Self::push`] that the prefill
    /// path uses so a T-token prompt costs one pool lock instead of T.
    /// All-or-nothing: on exhaustion every page taken so far is returned
    /// and the sequence is left unchanged, so the caller's fallback sees
    /// a consistent cache.
    pub fn reserve(&mut self, pool: &mut KvPool, extra: usize) -> Result<()> {
        let need =
            (self.len + extra).div_ceil(self.layout.page_tokens.max(1)) - self.pages.len();
        let mut taken = Vec::with_capacity(need);
        for _ in 0..need {
            match pool.take() {
                Ok(page) => taken.push(page),
                Err(e) => {
                    for page in taken {
                        pool.put(page);
                    }
                    return Err(e);
                }
            }
        }
        self.pages.extend(taken);
        self.len += extra;
        Ok(())
    }

    /// Drop every cached token, returning all pages to `pool`.
    pub fn clear(&mut self, pool: &mut KvPool) {
        for page in self.pages.drain(..) {
            pool.put(page);
        }
        self.len = 0;
    }

    #[inline]
    fn offsets(&self, t: usize, layer: usize) -> (usize, usize) {
        debug_assert!(t < self.len, "token {t} beyond cached {len}", len = self.len);
        debug_assert!(layer < self.layout.n_layers);
        let page = t / self.layout.page_tokens;
        let within = (t % self.layout.page_tokens) * self.layout.token_floats()
            + layer * 2 * self.layout.d_model;
        (page, within)
    }

    /// Cached (post-RoPE) key of token `t` at `layer`.
    #[inline]
    pub fn k(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        &self.pages[page][off..off + d]
    }

    /// Cached value of token `t` at `layer`.
    #[inline]
    pub fn v(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        &self.pages[page][off + d..off + 2 * d]
    }

    /// Mutable key/value buffers of token `t` at `layer` (for the write
    /// right after the projection matvecs).
    #[inline]
    pub fn kv_mut(&mut self, t: usize, layer: usize) -> (&mut [f32], &mut [f32]) {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        let slot = &mut self.pages[page][off..off + 2 * d];
        slot.split_at_mut(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, d_model: 8, page_tokens: 4 }
    }

    #[test]
    fn layout_sizes() {
        let l = layout();
        assert_eq!(l.token_floats(), 32);
        assert_eq!(l.page_floats(), 128);
    }

    #[test]
    fn push_write_read_roundtrip_across_pages() {
        let l = layout();
        let mut pool = KvPool::unbounded(l.page_floats());
        let mut seq = KvSeq::new(l);
        // 10 tokens spans 3 pages (4 tokens each)
        for t in 0..10 {
            seq.push(&mut pool).unwrap();
            for layer in 0..l.n_layers {
                let (k, v) = seq.kv_mut(t, layer);
                for (i, x) in k.iter_mut().enumerate() {
                    *x = (t * 100 + layer * 10 + i) as f32;
                }
                for (i, x) in v.iter_mut().enumerate() {
                    *x = -((t * 100 + layer * 10 + i) as f32);
                }
            }
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.n_pages(), 3);
        assert_eq!(pool.outstanding(), 3);
        for t in 0..10 {
            for layer in 0..l.n_layers {
                let k = seq.k(t, layer);
                let v = seq.v(t, layer);
                for i in 0..l.d_model {
                    assert_eq!(k[i], (t * 100 + layer * 10 + i) as f32);
                    assert_eq!(v[i], -((t * 100 + layer * 10 + i) as f32));
                }
            }
        }
        seq.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.free_pages(), 3);
    }

    #[test]
    fn reserve_matches_pushes_and_is_atomic() {
        let l = layout();
        // reserve(n) leaves the same geometry as n pushes
        let mut pool = KvPool::unbounded(l.page_floats());
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 10).unwrap();
        let mut b = KvSeq::new(l);
        for _ in 0..10 {
            b.push(&mut pool).unwrap();
        }
        assert_eq!((a.len(), a.n_pages()), (b.len(), b.n_pages()));
        // reserved slots are writable/readable immediately
        let (k, _) = a.kv_mut(9, 1);
        k[0] = 7.0;
        assert_eq!(a.k(9, 1)[0], 7.0);
        // growing an existing sequence only takes the missing pages
        a.reserve(&mut pool, 2).unwrap();
        assert_eq!((a.len(), a.n_pages()), (12, 3));
        a.clear(&mut pool);
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);

        // all-or-nothing on exhaustion: nothing taken, nothing mutated
        let mut small = KvPool::new(l.page_floats(), 2);
        let mut c = KvSeq::new(l);
        c.reserve(&mut small, 4).unwrap(); // exactly one page
        let err = c.reserve(&mut small, 8).unwrap_err(); // needs 2 more, cap allows 1
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "{err}");
        assert_eq!((c.len(), c.n_pages()), (4, 1), "failed reserve mutated the sequence");
        assert_eq!(small.outstanding(), 1, "failed reserve leaked pages");
        c.clear(&mut small);
    }

    #[test]
    fn pool_reuses_freed_pages() {
        let l = layout();
        let mut pool = KvPool::new(l.page_floats(), 4);
        let page = pool.take().unwrap();
        let ptr = page.as_ptr();
        pool.put(page);
        assert_eq!(pool.outstanding(), 0);
        // the very same buffer comes back (LIFO reuse), zeroed
        let page = pool.take().unwrap();
        assert_eq!(page.as_ptr(), ptr);
        assert!(page.iter().all(|&x| x == 0.0));
        pool.put(page);
    }

    #[test]
    fn pool_capacity_rejection_and_recovery() {
        let l = layout();
        let mut pool = KvPool::new(l.page_floats(), 2);
        let mut a = KvSeq::new(l);
        // 2 pages worth of tokens fit; the 9th token needs a 3rd page
        for _ in 0..8 {
            a.push(&mut pool).unwrap();
        }
        assert_eq!(pool.outstanding(), 2);
        let err = a.push(&mut pool).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // the typed error survives downcasting (the backend's fallback key)
        let typed = err.downcast_ref::<KvExhausted>().expect("typed exhaustion error");
        assert_eq!(typed.outstanding, 2);
        // a failed push leaves the sequence usable and consistent
        assert_eq!(a.len(), 8);
        // freeing makes capacity available again
        a.clear(&mut pool);
        let mut b = KvSeq::new(l);
        for _ in 0..8 {
            b.push(&mut pool).unwrap();
        }
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }
}
