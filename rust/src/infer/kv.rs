//! Paged key/value cache for the native inference backend.
//!
//! The cache turns batched greedy decode from O(T²) per emitted token
//! (recompute attention over the whole window every step) into O(T): a
//! slot's keys and values are computed once, stored, and only the newest
//! token runs through the linear stack each step.
//!
//! Two pieces:
//!
//! * [`KvPool`] — the shared page budget. Pages are fixed-size boxed
//!   buffers in the pool's element format ([`KvFormat`]); freed pages go
//!   to a free list and are handed back out before anything new is
//!   allocated, so steady-state serving does no allocation. `take` fails
//!   once `max_pages` buffers are outstanding — callers (the native
//!   backend) fall back to uncached compute rather than grow without
//!   bound.
//! * [`KvSeq`] — one slot's cache: a queue of pages it exclusively owns,
//!   holding `[n_layers, 2, d_model]` elements per cached token (keys are
//!   stored *post-RoPE*, values raw). Because each sequence owns its
//!   pages outright, a batch of slots can be processed fully in parallel
//!   with no locking on the hot path; the pool mutex is touched only at
//!   page-boundary alloc/free.
//!
//! The element format is pluggable: `f32` stores rows verbatim (reads are
//! zero-copy borrows, the cached path stays bit-exact against uncached
//! compute), while `e4m3` packs each element to one FP8 byte through
//! [`crate::formats::e4m3`] — 4x more cached tokens per pool budget and
//! ~4x less attention read bandwidth, at the cost of quantization error
//! (the one deliberately non-bit-exact path; see the tolerance tests).
//! Writes go through [`KvSeq::store_kv`], reads through
//! [`KvSeq::k_row`]/[`KvSeq::v_row`], which borrow for `f32` and decode
//! into a caller scratch row for `e4m3`.
//!
//! Slot lifecycle (allocate on admit, free on completion/disconnect) is
//! driven by the scheduler through `StepBackend::release` — see
//! `serve::scheduler` and [`super::NativeBackend`].

use std::collections::VecDeque;

use anyhow::Result;

use crate::formats::e4m3;

/// Typed error returned by [`KvPool::take`] when the page budget is
/// spent. The native backend downcasts to this (`downcast_ref`, which
/// survives any `context` wrapping) to pick the uncached-compute
/// fallback instead of failing the request.
#[derive(Clone, Copy, Debug)]
pub struct KvExhausted {
    /// pages outstanding when the take failed
    pub outstanding: usize,
}

impl std::fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv pool exhausted ({} pages outstanding)", self.outstanding)
    }
}

impl std::error::Error for KvExhausted {}

/// Element storage format for cached K/V rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvFormat {
    /// Raw `f32` — zero-copy reads, cached decode stays bit-exact.
    F32,
    /// FP8 E4M3, one byte per element — 4x the cached tokens per byte
    /// budget, small quantization error on attention scores.
    E4m3,
}

impl KvFormat {
    /// CLI/bench name of the format.
    pub fn name(self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::E4m3 => "e4m3",
        }
    }

    /// Parse a CLI name (`f32` / `e4m3`), case-insensitive.
    pub fn parse(s: &str) -> Option<KvFormat> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Some(KvFormat::F32),
            "e4m3" | "fp8" => Some(KvFormat::E4m3),
            _ => None,
        }
    }

    /// Bytes one stored element occupies.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvFormat::F32 => 4,
            KvFormat::E4m3 => 1,
        }
    }
}

/// Geometry of one cached token slot: how many elements a token occupies,
/// how tokens tile into pages, and how elements are stored.
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// decoder layers
    pub n_layers: usize,
    /// model width (keys and values are `[d_model]` each per layer)
    pub d_model: usize,
    /// cached tokens per page
    pub page_tokens: usize,
    /// element storage format
    pub format: KvFormat,
}

impl KvLayout {
    /// Elements one cached token occupies (`n_layers * 2 * d_model`).
    pub fn token_floats(&self) -> usize {
        self.n_layers * 2 * self.d_model
    }

    /// Elements per page.
    pub fn page_floats(&self) -> usize {
        self.page_tokens * self.token_floats()
    }

    /// Bytes per page in the storage format — the number that decides how
    /// many slots a fixed memory budget holds.
    pub fn page_bytes(&self) -> usize {
        self.page_floats() * self.format.elem_bytes()
    }
}

/// One pool page: storage for `page_tokens` cached token slots, in the
/// pool's element format. Variants never mix within a pool.
#[derive(Debug)]
pub enum KvPage {
    /// `f32` storage, `page_floats` elements.
    F32(Box<[f32]>),
    /// E4M3-packed storage, one byte per element.
    Bytes(Box<[u8]>),
}

impl KvPage {
    fn zero(&mut self) {
        match self {
            KvPage::F32(p) => p.fill(0.0),
            KvPage::Bytes(p) => p.fill(0),
        }
    }

    fn elems(&self) -> usize {
        match self {
            KvPage::F32(p) => p.len(),
            KvPage::Bytes(p) => p.len(),
        }
    }
}

/// Bounded page allocator shared by every slot of a native backend.
///
/// Freed pages are recycled (LIFO) before new ones are allocated, and the
/// total outstanding count never exceeds `max_pages`.
#[derive(Debug)]
pub struct KvPool {
    format: KvFormat,
    page_floats: usize,
    max_pages: usize,
    outstanding: usize,
    free: Vec<KvPage>,
}

impl KvPool {
    /// A pool handing out pages shaped for `layout`, at most `max_pages`
    /// outstanding at once.
    pub fn new(layout: KvLayout, max_pages: usize) -> KvPool {
        KvPool {
            format: layout.format,
            page_floats: layout.page_floats(),
            max_pages,
            outstanding: 0,
            free: Vec::new(),
        }
    }

    /// An effectively unbounded pool (scratch compute, tests).
    pub fn unbounded(layout: KvLayout) -> KvPool {
        KvPool::new(layout, usize::MAX)
    }

    /// Element format of every page this pool hands out.
    pub fn format(&self) -> KvFormat {
        self.format
    }

    /// Take one page, reusing a freed buffer when available. Errors once
    /// the outstanding count reaches the pool cap.
    pub fn take(&mut self) -> Result<KvPage> {
        if let Some(mut page) = self.free.pop() {
            page.zero();
            self.outstanding += 1;
            return Ok(page);
        }
        if self.outstanding >= self.max_pages {
            return Err(anyhow::Error::new(KvExhausted { outstanding: self.outstanding }));
        }
        self.outstanding += 1;
        Ok(match self.format {
            KvFormat::F32 => KvPage::F32(vec![0.0f32; self.page_floats].into_boxed_slice()),
            KvFormat::E4m3 => KvPage::Bytes(vec![0u8; self.page_floats].into_boxed_slice()),
        })
    }

    /// Return a page to the free list.
    pub fn put(&mut self, page: KvPage) {
        debug_assert_eq!(page.elems(), self.page_floats, "foreign page returned");
        debug_assert!(
            matches!(
                (&page, self.format),
                (KvPage::F32(_), KvFormat::F32) | (KvPage::Bytes(_), KvFormat::E4m3)
            ),
            "page format does not match pool format"
        );
        debug_assert!(self.outstanding > 0, "put without matching take");
        self.outstanding = self.outstanding.saturating_sub(1);
        self.free.push(page);
    }

    /// Pages currently held by sequences (not in the free list).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Recycled pages waiting to be reused.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// The outstanding-page cap.
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }
}

/// One slot's cached keys/values: an append-only queue of owned pages.
///
/// Token `t`'s layer-`l` entries live at a fixed offset for the slot's
/// lifetime, so references handed out by [`Self::k`]/[`Self::v`] stay
/// valid across appends (pages are never moved, only pushed). The
/// sequence must be drained back into its pool with [`Self::clear`]
/// before it is dropped — the backend does this in `release`.
#[derive(Debug)]
pub struct KvSeq {
    layout: KvLayout,
    pages: VecDeque<KvPage>,
    len: usize,
}

impl KvSeq {
    /// An empty sequence for `layout`.
    pub fn new(layout: KvLayout) -> KvSeq {
        KvSeq { layout, pages: VecDeque::new(), len: 0 }
    }

    /// Cached tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tokens are cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Element format rows are stored in.
    pub fn format(&self) -> KvFormat {
        self.layout.format
    }

    /// Append one token slot (zero-initialized), taking a new page from
    /// `pool` when the tail page is full. On pool exhaustion the sequence
    /// is left unchanged and the caller decides the fallback.
    pub fn push(&mut self, pool: &mut KvPool) -> Result<()> {
        if self.len % self.layout.page_tokens == 0 {
            self.pages.push_back(pool.take()?);
        }
        self.len += 1;
        Ok(())
    }

    /// Reserve `extra` token slots (zero-initialized) in one pool
    /// transaction — the bulk form of [`Self::push`] that the prefill
    /// path uses so a T-token prompt costs one pool lock instead of T.
    /// All-or-nothing: on exhaustion every page taken so far is returned
    /// and the sequence is left unchanged, so the caller's fallback sees
    /// a consistent cache.
    pub fn reserve(&mut self, pool: &mut KvPool, extra: usize) -> Result<()> {
        let need =
            (self.len + extra).div_ceil(self.layout.page_tokens.max(1)) - self.pages.len();
        let mut taken = Vec::with_capacity(need);
        for _ in 0..need {
            match pool.take() {
                Ok(page) => taken.push(page),
                Err(e) => {
                    for page in taken {
                        pool.put(page);
                    }
                    return Err(e);
                }
            }
        }
        self.pages.extend(taken);
        self.len += extra;
        Ok(())
    }

    /// Drop every cached token, returning all pages to `pool`.
    pub fn clear(&mut self, pool: &mut KvPool) {
        for page in self.pages.drain(..) {
            pool.put(page);
        }
        self.len = 0;
    }

    #[inline]
    fn offsets(&self, t: usize, layer: usize) -> (usize, usize) {
        debug_assert!(t < self.len, "token {t} beyond cached {len}", len = self.len);
        debug_assert!(layer < self.layout.n_layers);
        let page = t / self.layout.page_tokens;
        let within = (t % self.layout.page_tokens) * self.layout.token_floats()
            + layer * 2 * self.layout.d_model;
        (page, within)
    }

    /// Write token `t`'s layer-`layer` key and value rows, encoding
    /// through the layout's element format. This is the one write path
    /// that works for every format — projections land in scratch and are
    /// stored from there.
    pub fn store_kv(&mut self, t: usize, layer: usize, k: &[f32], v: &[f32]) {
        let d = self.layout.d_model;
        assert_eq!(k.len(), d, "key row width mismatch");
        assert_eq!(v.len(), d, "value row width mismatch");
        let (page, off) = self.offsets(t, layer);
        match &mut self.pages[page] {
            KvPage::F32(p) => {
                p[off..off + d].copy_from_slice(k);
                p[off + d..off + 2 * d].copy_from_slice(v);
            }
            KvPage::Bytes(p) => {
                e4m3::encode_slice(k, &mut p[off..off + d]);
                e4m3::encode_slice(v, &mut p[off + d..off + 2 * d]);
            }
        }
    }

    /// Key row of token `t` at `layer` as f32: a zero-copy borrow for
    /// `f32` storage, or an E4M3 decode into `buf[..d_model]` (which must
    /// be at least `d_model` long). The attention loops pass a per-row
    /// scratch buffer so each cached row is decoded at most once per use.
    #[inline]
    pub fn k_row<'a>(&'a self, t: usize, layer: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match &self.pages[page] {
            KvPage::F32(p) => &p[off..off + d],
            KvPage::Bytes(p) => {
                e4m3::decode_slice(&p[off..off + d], &mut buf[..d]);
                &buf[..d]
            }
        }
    }

    /// Value row of token `t` at `layer` as f32 — same contract as
    /// [`Self::k_row`].
    #[inline]
    pub fn v_row<'a>(&'a self, t: usize, layer: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match &self.pages[page] {
            KvPage::F32(p) => &p[off + d..off + 2 * d],
            KvPage::Bytes(p) => {
                e4m3::decode_slice(&p[off + d..off + 2 * d], &mut buf[..d]);
                &buf[..d]
            }
        }
    }

    /// Cached (post-RoPE) key of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — quantized rows have no borrowable f32 view;
    /// use [`Self::k_row`] with a scratch buffer instead.
    #[inline]
    pub fn k(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match &self.pages[page] {
            KvPage::F32(p) => &p[off..off + d],
            KvPage::Bytes(_) => panic!("KvSeq::k needs f32 kv storage; use k_row"),
        }
    }

    /// Cached value of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — use [`Self::v_row`] instead.
    #[inline]
    pub fn v(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match &self.pages[page] {
            KvPage::F32(p) => &p[off + d..off + 2 * d],
            KvPage::Bytes(_) => panic!("KvSeq::v needs f32 kv storage; use v_row"),
        }
    }

    /// Mutable key/value buffers of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — quantized writes must re-encode whole rows;
    /// use [`Self::store_kv`] instead.
    #[inline]
    pub fn kv_mut(&mut self, t: usize, layer: usize) -> (&mut [f32], &mut [f32]) {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match &mut self.pages[page] {
            KvPage::F32(p) => p[off..off + 2 * d].split_at_mut(d),
            KvPage::Bytes(_) => panic!("KvSeq::kv_mut needs f32 kv storage; use store_kv"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, d_model: 8, page_tokens: 4, format: KvFormat::F32 }
    }

    #[test]
    fn layout_sizes() {
        let l = layout();
        assert_eq!(l.token_floats(), 32);
        assert_eq!(l.page_floats(), 128);
        assert_eq!(l.page_bytes(), 512);
        let q = KvLayout { format: KvFormat::E4m3, ..l };
        assert_eq!(q.page_floats(), 128);
        assert_eq!(q.page_bytes(), 128, "e4m3 pages are 4x smaller");
    }

    #[test]
    fn format_names_parse() {
        for f in [KvFormat::F32, KvFormat::E4m3] {
            assert_eq!(KvFormat::parse(f.name()), Some(f));
        }
        assert_eq!(KvFormat::parse("E4M3"), Some(KvFormat::E4m3));
        assert_eq!(KvFormat::parse("fp8"), Some(KvFormat::E4m3));
        assert_eq!(KvFormat::parse("f16"), None);
    }

    #[test]
    fn push_write_read_roundtrip_across_pages() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        // 10 tokens spans 3 pages (4 tokens each)
        for t in 0..10 {
            seq.push(&mut pool).unwrap();
            for layer in 0..l.n_layers {
                let (k, v) = seq.kv_mut(t, layer);
                for (i, x) in k.iter_mut().enumerate() {
                    *x = (t * 100 + layer * 10 + i) as f32;
                }
                for (i, x) in v.iter_mut().enumerate() {
                    *x = -((t * 100 + layer * 10 + i) as f32);
                }
            }
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.n_pages(), 3);
        assert_eq!(pool.outstanding(), 3);
        let mut buf = vec![0.0f32; l.d_model];
        for t in 0..10 {
            for layer in 0..l.n_layers {
                let k = seq.k(t, layer);
                let v = seq.v(t, layer);
                for i in 0..l.d_model {
                    assert_eq!(k[i], (t * 100 + layer * 10 + i) as f32);
                    assert_eq!(v[i], -((t * 100 + layer * 10 + i) as f32));
                }
                // the row views agree bitwise with the borrows on f32
                let kr: Vec<f32> = seq.k_row(t, layer, &mut buf).to_vec();
                assert_eq!(kr, seq.k(t, layer));
                let vr: Vec<f32> = seq.v_row(t, layer, &mut buf).to_vec();
                assert_eq!(vr, seq.v(t, layer));
            }
        }
        seq.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.free_pages(), 3);
    }

    #[test]
    fn e4m3_store_read_roundtrips_through_codec() {
        let l = KvLayout { format: KvFormat::E4m3, ..layout() };
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        let d = l.d_model;
        // values spanning subnormal, normal, negative, and saturating range
        let mk = |t: usize, layer: usize, i: usize, sign: f32| {
            sign * (0.001 + (t * 37 + layer * 11 + i * 3) as f32 * 1.7)
        };
        for t in 0..9 {
            seq.push(&mut pool).unwrap();
            for layer in 0..l.n_layers {
                let k: Vec<f32> = (0..d).map(|i| mk(t, layer, i, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|i| mk(t, layer, i, -1.0)).collect();
                seq.store_kv(t, layer, &k, &v);
            }
        }
        assert_eq!(seq.n_pages(), 3);
        let mut buf = vec![0.0f32; d];
        for t in 0..9 {
            for layer in 0..l.n_layers {
                for i in 0..d {
                    let want_k = e4m3::roundtrip(mk(t, layer, i, 1.0).min(e4m3::E4M3_MAX));
                    let got_k = seq.k_row(t, layer, &mut buf)[i];
                    assert_eq!(got_k.to_bits(), want_k.to_bits(), "k t={t} l={layer} i={i}");
                    let want_v =
                        e4m3::roundtrip(mk(t, layer, i, -1.0).max(-e4m3::E4M3_MAX));
                    let got_v = seq.v_row(t, layer, &mut buf)[i];
                    assert_eq!(got_v.to_bits(), want_v.to_bits(), "v t={t} l={layer} i={i}");
                }
            }
        }
        seq.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    #[should_panic(expected = "f32 kv storage")]
    fn borrow_views_reject_quantized_storage() {
        let l = KvLayout { format: KvFormat::E4m3, ..layout() };
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        seq.push(&mut pool).unwrap();
        let _ = seq.k(0, 0);
    }

    #[test]
    fn reserve_matches_pushes_and_is_atomic() {
        let l = layout();
        // reserve(n) leaves the same geometry as n pushes
        let mut pool = KvPool::unbounded(l);
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 10).unwrap();
        let mut b = KvSeq::new(l);
        for _ in 0..10 {
            b.push(&mut pool).unwrap();
        }
        assert_eq!((a.len(), a.n_pages()), (b.len(), b.n_pages()));
        // reserved slots are writable/readable immediately
        let (k, _) = a.kv_mut(9, 1);
        k[0] = 7.0;
        assert_eq!(a.k(9, 1)[0], 7.0);
        // growing an existing sequence only takes the missing pages
        a.reserve(&mut pool, 2).unwrap();
        assert_eq!((a.len(), a.n_pages()), (12, 3));
        a.clear(&mut pool);
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);

        // all-or-nothing on exhaustion: nothing taken, nothing mutated
        let mut small = KvPool::new(l, 2);
        let mut c = KvSeq::new(l);
        c.reserve(&mut small, 4).unwrap(); // exactly one page
        let err = c.reserve(&mut small, 8).unwrap_err(); // needs 2 more, cap allows 1
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "{err}");
        assert_eq!((c.len(), c.n_pages()), (4, 1), "failed reserve mutated the sequence");
        assert_eq!(small.outstanding(), 1, "failed reserve leaked pages");
        c.clear(&mut small);
    }

    #[test]
    fn pool_reuses_freed_pages() {
        let l = layout();
        let mut pool = KvPool::new(l, 4);
        let page = pool.take().unwrap();
        let ptr = match &page {
            KvPage::F32(p) => p.as_ptr(),
            KvPage::Bytes(_) => unreachable!("f32 pool handed out a byte page"),
        };
        pool.put(page);
        assert_eq!(pool.outstanding(), 0);
        // the very same buffer comes back (LIFO reuse), zeroed
        let page = pool.take().unwrap();
        match &page {
            KvPage::F32(p) => {
                assert_eq!(p.as_ptr(), ptr);
                assert!(p.iter().all(|&x| x == 0.0));
            }
            KvPage::Bytes(_) => unreachable!(),
        }
        pool.put(page);
    }

    #[test]
    fn pool_capacity_rejection_and_recovery() {
        let l = layout();
        let mut pool = KvPool::new(l, 2);
        let mut a = KvSeq::new(l);
        // 2 pages worth of tokens fit; the 9th token needs a 3rd page
        for _ in 0..8 {
            a.push(&mut pool).unwrap();
        }
        assert_eq!(pool.outstanding(), 2);
        let err = a.push(&mut pool).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // the typed error survives downcasting (the backend's fallback key)
        let typed = err.downcast_ref::<KvExhausted>().expect("typed exhaustion error");
        assert_eq!(typed.outstanding, 2);
        // a failed push leaves the sequence usable and consistent
        assert_eq!(a.len(), 8);
        // freeing makes capacity available again
        a.clear(&mut pool);
        let mut b = KvSeq::new(l);
        for _ in 0..8 {
            b.push(&mut pool).unwrap();
        }
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }
}
