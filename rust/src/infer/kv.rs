//! Paged key/value cache for the native inference backend.
//!
//! The cache turns batched greedy decode from O(T²) per emitted token
//! (recompute attention over the whole window every step) into O(T): a
//! slot's keys and values are computed once, stored, and only the newest
//! token runs through the linear stack each step.
//!
//! Two pieces:
//!
//! * [`KvPool`] — the shared page budget. Pages are fixed-size boxed
//!   buffers in the pool's element format ([`KvFormat`]); freed pages go
//!   to a free list and are handed back out before anything new is
//!   allocated, so steady-state serving does no allocation. `take` fails
//!   once `max_pages` buffers are outstanding — callers (the native
//!   backend) fall back to uncached compute rather than grow without
//!   bound.
//! * [`KvSeq`] — one slot's cache: a list of **refcounted** pages
//!   (`Arc<KvPage>`) holding `[n_layers, 2, d_model]` elements per
//!   cached token (keys are stored *post-RoPE*, values raw).
//!
//! Pages are refcounted so several sequences can share a common prompt
//! prefix (the prefix cache in [`super::prefix`]) without copying: a
//! **full** page's handle can be attached to another sequence with
//! [`KvSeq::attach`], and every holder returns its handle through
//! [`KvPool::release`] — the buffer goes back to the free list exactly
//! once, when the *last* handle is released. Writes stay lock-free and
//! copy-free on the hot path because only full (immutable) pages are
//! ever shared: every write targets a refcount-1 page via
//! [`std::sync::Arc::get_mut`], and a shared *partial* tail page (which
//! the backend never produces, but the API cannot forbid) is
//! copied-on-write at the next `push`/`reserve` instead of being
//! mutated in place.
//!
//! The element format is pluggable: `f32` stores rows verbatim (reads are
//! zero-copy borrows, the cached path stays bit-exact against uncached
//! compute), while `e4m3` packs each element to one FP8 byte through
//! [`crate::formats::e4m3`] — 4x more cached tokens per pool budget and
//! ~4x less attention read bandwidth, at the cost of quantization error
//! (the one deliberately non-bit-exact path; see the tolerance tests).
//! Writes go through [`KvSeq::store_kv`], reads through
//! [`KvSeq::k_row`]/[`KvSeq::v_row`], which borrow for `f32` and decode
//! into a caller scratch row for `e4m3`.
//!
//! Slot lifecycle (allocate on admit, free on completion/disconnect) is
//! driven by the scheduler through `StepBackend::release` — see
//! `serve::scheduler` and [`super::NativeBackend`]. Every `Arc` handle
//! a sequence or the prefix trie holds must be returned through
//! [`KvPool::release`] (never just dropped), or the pool's outstanding
//! count — the leak-detection signal the drain tests assert on — would
//! overcount.

use std::sync::Arc;

use anyhow::Result;

use crate::formats::e4m3;

/// Typed error returned by [`KvPool::take`] when the page budget is
/// spent. The native backend downcasts to this (`downcast_ref`, which
/// survives any `context` wrapping) to pick the uncached-compute
/// fallback instead of failing the request.
#[derive(Clone, Copy, Debug)]
pub struct KvExhausted {
    /// pages outstanding when the take failed
    pub outstanding: usize,
}

impl std::fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv pool exhausted ({} pages outstanding)", self.outstanding)
    }
}

impl std::error::Error for KvExhausted {}

/// Element storage format for cached K/V rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvFormat {
    /// Raw `f32` — zero-copy reads, cached decode stays bit-exact.
    F32,
    /// FP8 E4M3, one byte per element — 4x the cached tokens per byte
    /// budget, small quantization error on attention scores.
    E4m3,
}

impl KvFormat {
    /// CLI/bench name of the format.
    pub fn name(self) -> &'static str {
        match self {
            KvFormat::F32 => "f32",
            KvFormat::E4m3 => "e4m3",
        }
    }

    /// Parse a CLI name (`f32` / `e4m3`), case-insensitive.
    pub fn parse(s: &str) -> Option<KvFormat> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Some(KvFormat::F32),
            "e4m3" | "fp8" => Some(KvFormat::E4m3),
            _ => None,
        }
    }

    /// Bytes one stored element occupies.
    pub fn elem_bytes(self) -> usize {
        match self {
            KvFormat::F32 => 4,
            KvFormat::E4m3 => 1,
        }
    }
}

/// Geometry of one cached token slot: how many elements a token occupies,
/// how tokens tile into pages, and how elements are stored.
#[derive(Clone, Copy, Debug)]
pub struct KvLayout {
    /// decoder layers
    pub n_layers: usize,
    /// model width (keys and values are `[d_model]` each per layer)
    pub d_model: usize,
    /// cached tokens per page
    pub page_tokens: usize,
    /// element storage format
    pub format: KvFormat,
}

impl KvLayout {
    /// Elements one cached token occupies (`n_layers * 2 * d_model`).
    pub fn token_floats(&self) -> usize {
        self.n_layers * 2 * self.d_model
    }

    /// Elements per page.
    pub fn page_floats(&self) -> usize {
        self.page_tokens * self.token_floats()
    }

    /// Bytes per page in the storage format — the number that decides how
    /// many slots a fixed memory budget holds.
    pub fn page_bytes(&self) -> usize {
        self.page_floats() * self.format.elem_bytes()
    }
}

/// One pool page: storage for `page_tokens` cached token slots, in the
/// pool's element format. Variants never mix within a pool.
#[derive(Debug)]
pub enum KvPage {
    /// `f32` storage, `page_floats` elements.
    F32(Box<[f32]>),
    /// E4M3-packed storage, one byte per element.
    Bytes(Box<[u8]>),
}

impl KvPage {
    fn zero(&mut self) {
        match self {
            KvPage::F32(p) => p.fill(0.0),
            KvPage::Bytes(p) => p.fill(0),
        }
    }

    fn elems(&self) -> usize {
        match self {
            KvPage::F32(p) => p.len(),
            KvPage::Bytes(p) => p.len(),
        }
    }

    fn copy_from(&mut self, src: &KvPage) {
        match (self, src) {
            (KvPage::F32(dst), KvPage::F32(src)) => dst.copy_from_slice(src),
            (KvPage::Bytes(dst), KvPage::Bytes(src)) => dst.copy_from_slice(src),
            _ => panic!("kv page format mismatch on copy"),
        }
    }
}

/// Bounded page allocator shared by every slot of a native backend.
///
/// Freed pages are recycled (LIFO) before new ones are allocated, and the
/// total outstanding count never exceeds `max_pages`. With refcounted
/// sharing, `outstanding` counts *physical* pages: a page attached to
/// three sequences counts once, and returns to the free list only when
/// the last holder calls [`Self::release`].
#[derive(Debug)]
pub struct KvPool {
    format: KvFormat,
    page_floats: usize,
    max_pages: usize,
    outstanding: usize,
    hwm: usize,
    free: Vec<KvPage>,
}

impl KvPool {
    /// A pool handing out pages shaped for `layout`, at most `max_pages`
    /// outstanding at once.
    pub fn new(layout: KvLayout, max_pages: usize) -> KvPool {
        KvPool {
            format: layout.format,
            page_floats: layout.page_floats(),
            max_pages,
            outstanding: 0,
            hwm: 0,
            free: Vec::new(),
        }
    }

    /// An effectively unbounded pool (scratch compute, tests).
    pub fn unbounded(layout: KvLayout) -> KvPool {
        KvPool::new(layout, usize::MAX)
    }

    /// Element format of every page this pool hands out.
    pub fn format(&self) -> KvFormat {
        self.format
    }

    /// Take one page, reusing a freed buffer when available. Errors once
    /// the outstanding count reaches the pool cap.
    pub fn take(&mut self) -> Result<KvPage> {
        if let Some(mut page) = self.free.pop() {
            page.zero();
            self.outstanding += 1;
            self.hwm = self.hwm.max(self.outstanding);
            return Ok(page);
        }
        if self.outstanding >= self.max_pages {
            return Err(anyhow::Error::new(KvExhausted { outstanding: self.outstanding }));
        }
        self.outstanding += 1;
        self.hwm = self.hwm.max(self.outstanding);
        Ok(match self.format {
            KvFormat::F32 => KvPage::F32(vec![0.0f32; self.page_floats].into_boxed_slice()),
            KvFormat::E4m3 => KvPage::Bytes(vec![0u8; self.page_floats].into_boxed_slice()),
        })
    }

    /// Return a page to the free list.
    pub fn put(&mut self, page: KvPage) {
        debug_assert_eq!(page.elems(), self.page_floats, "foreign page returned");
        debug_assert!(
            matches!(
                (&page, self.format),
                (KvPage::F32(_), KvFormat::F32) | (KvPage::Bytes(_), KvFormat::E4m3)
            ),
            "page format does not match pool format"
        );
        debug_assert!(self.outstanding > 0, "put without matching take");
        self.outstanding = self.outstanding.saturating_sub(1);
        self.free.push(page);
    }

    /// Drop one refcounted handle on a page, returning the buffer to the
    /// free list when (and only when) this was the *last* handle — the
    /// exactly-once free that makes prefix sharing leak-proof. Handles
    /// must always come back through here (not a plain `drop`), or the
    /// outstanding count would never reach zero.
    pub fn release(&mut self, page: Arc<KvPage>) {
        if let Ok(page) = Arc::try_unwrap(page) {
            self.put(page);
        }
    }

    /// Pages currently held by sequences or the prefix trie (not in the
    /// free list). Counts physical pages, not handles.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// Peak value [`Self::outstanding`] ever reached — the pages-in-use
    /// high-water mark surfaced in the serve stats.
    pub fn high_water(&self) -> usize {
        self.hwm
    }

    /// Recycled pages waiting to be reused.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// The outstanding-page cap.
    pub fn max_pages(&self) -> usize {
        self.max_pages
    }
}

/// One slot's cached keys/values: an append-only list of refcounted
/// pages.
///
/// Token `t`'s layer-`l` entries live at a fixed offset for the slot's
/// lifetime, so references handed out by [`Self::k`]/[`Self::v`] stay
/// valid across appends (pages are never moved, only pushed). The
/// sequence must be drained back into its pool with [`Self::clear`]
/// before it is dropped — the backend does this in `release`.
///
/// A sequence may hold two kinds of pages: pages it took from the pool
/// itself (refcount 1 — writable), and **full** pages attached from
/// another sequence's prompt via [`Self::attach`] (shared — read-only).
/// Writes ([`Self::store_kv`] / [`Self::kv_mut`]) panic on a shared
/// page; the backend's only-full-pages-are-shared discipline guarantees
/// every write lands on an exclusive page, and a shared partial tail is
/// defensively copied-on-write by [`Self::push`]/[`Self::reserve`].
#[derive(Debug)]
pub struct KvSeq {
    layout: KvLayout,
    pages: Vec<Arc<KvPage>>,
    len: usize,
}

impl KvSeq {
    /// An empty sequence for `layout`.
    pub fn new(layout: KvLayout) -> KvSeq {
        KvSeq { layout, pages: Vec::new(), len: 0 }
    }

    /// Cached tokens.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tokens are cached.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pages currently held.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Element format rows are stored in.
    pub fn format(&self) -> KvFormat {
        self.layout.format
    }

    /// A refcounted handle to page `i` — how the prefix trie publishes a
    /// prompt's full pages for other sequences to [`Self::attach`]. The
    /// holder must eventually return the handle through
    /// [`KvPool::release`].
    pub fn page_handle(&self, i: usize) -> Arc<KvPage> {
        Arc::clone(&self.pages[i])
    }

    /// Refcount on page `i` (1 = exclusively owned). Test/diagnostic
    /// visibility into the sharing state.
    pub fn page_refs(&self, i: usize) -> usize {
        Arc::strong_count(&self.pages[i])
    }

    /// Append a shared **full** page: the sequence gains `page_tokens`
    /// cached tokens without touching the pool. The cache-hit admission
    /// path uses this to reuse another request's prompt pages.
    ///
    /// # Panics
    /// When the sequence is not at a full-page boundary — only whole
    /// pages can be shared, or token offsets would shift.
    pub fn attach(&mut self, page: Arc<KvPage>) {
        assert_eq!(
            self.len % self.layout.page_tokens,
            0,
            "attach requires a full-page boundary (len {})",
            self.len
        );
        debug_assert_eq!(page.elems(), self.layout.page_floats(), "foreign page attached");
        self.pages.push(page);
        self.len += self.layout.page_tokens;
    }

    /// Append one token slot (zero-initialized), taking a new page from
    /// `pool` when the tail page is full. On pool exhaustion the sequence
    /// is left unchanged and the caller decides the fallback.
    pub fn push(&mut self, pool: &mut KvPool) -> Result<()> {
        if self.len % self.layout.page_tokens == 0 {
            self.pages.push(Arc::new(pool.take()?));
        } else {
            self.cow_tail(pool)?;
        }
        self.len += 1;
        Ok(())
    }

    /// Reserve `extra` token slots (zero-initialized) in one pool
    /// transaction — the bulk form of [`Self::push`] that the prefill
    /// path uses so a T-token prompt costs one pool lock instead of T.
    /// All-or-nothing: on exhaustion every page taken so far is returned
    /// and the sequence is left unchanged, so the caller's fallback sees
    /// a consistent cache. (A defensive copy-on-write of a shared
    /// partial tail page may still have happened — it changes no
    /// contents and no geometry.)
    pub fn reserve(&mut self, pool: &mut KvPool, extra: usize) -> Result<()> {
        if extra == 0 {
            return Ok(());
        }
        if self.len % self.layout.page_tokens != 0 {
            self.cow_tail(pool)?;
        }
        let need =
            (self.len + extra).div_ceil(self.layout.page_tokens.max(1)) - self.pages.len();
        let mut taken = Vec::with_capacity(need);
        for _ in 0..need {
            match pool.take() {
                Ok(page) => taken.push(Arc::new(page)),
                Err(e) => {
                    for page in taken {
                        pool.release(page);
                    }
                    return Err(e);
                }
            }
        }
        self.pages.extend(taken);
        self.len += extra;
        Ok(())
    }

    /// Drop every cached token, releasing all page handles back to
    /// `pool` (a shared page is freed only when its last holder lets go).
    pub fn clear(&mut self, pool: &mut KvPool) {
        for page in self.pages.drain(..) {
            pool.release(page);
        }
        self.len = 0;
    }

    /// Drop every cached token beyond `keep`, releasing pages that
    /// become wholly unused — the rollback primitive speculative decode
    /// uses to discard rejected draft tokens without rebuilding the
    /// whole sequence.
    ///
    /// Returns the **actual** new length, which can be less than `keep`:
    /// when the boundary lands inside a *shared* page (refcount > 1 —
    /// an attached prefix page), the shared handle is released too and
    /// the sequence shrinks to the previous page boundary, because a
    /// shared page is immutable by contract and its tail slots could
    /// never be rewritten. Callers re-prefill the gap (the backend's
    /// `catch_up` does exactly that). A `keep >= len` is a no-op.
    pub fn truncate(&mut self, pool: &mut KvPool, keep: usize) -> usize {
        if keep >= self.len {
            return self.len;
        }
        let pt = self.layout.page_tokens.max(1);
        let mut need_pages = keep.div_ceil(pt);
        for page in self.pages.drain(need_pages..) {
            pool.release(page);
        }
        self.len = keep;
        if keep % pt != 0 {
            if let Some(last) = self.pages.last() {
                if Arc::strong_count(last) > 1 {
                    need_pages -= 1;
                    let shared = self.pages.pop().expect("tail page just observed");
                    pool.release(shared);
                    self.len = need_pages * pt;
                }
            }
        }
        self.len
    }

    /// Ensure the tail page is exclusively owned before it is written:
    /// when shared, its contents are copied into a fresh pool page and
    /// the shared handle is released. The backend shares only full
    /// pages, so this is a defensive guard, not a hot path.
    fn cow_tail(&mut self, pool: &mut KvPool) -> Result<()> {
        let last = match self.pages.len().checked_sub(1) {
            Some(i) => i,
            None => return Ok(()),
        };
        if Arc::get_mut(&mut self.pages[last]).is_some() {
            return Ok(());
        }
        let mut fresh = pool.take()?;
        fresh.copy_from(&self.pages[last]);
        let shared = std::mem::replace(&mut self.pages[last], Arc::new(fresh));
        pool.release(shared);
        Ok(())
    }

    #[inline]
    fn offsets(&self, t: usize, layer: usize) -> (usize, usize) {
        debug_assert!(t < self.len, "token {t} beyond cached {len}", len = self.len);
        debug_assert!(layer < self.layout.n_layers);
        let page = t / self.layout.page_tokens;
        let within = (t % self.layout.page_tokens) * self.layout.token_floats()
            + layer * 2 * self.layout.d_model;
        (page, within)
    }

    #[inline]
    fn page_mut(&mut self, page: usize) -> &mut KvPage {
        Arc::get_mut(&mut self.pages[page]).expect("write to shared kv page")
    }

    /// Write token `t`'s layer-`layer` key and value rows, encoding
    /// through the layout's element format. This is the one write path
    /// that works for every format — projections land in scratch and are
    /// stored from there.
    ///
    /// # Panics
    /// When the page holding token `t` is shared (refcount > 1) — shared
    /// prefix pages are immutable by contract.
    pub fn store_kv(&mut self, t: usize, layer: usize, k: &[f32], v: &[f32]) {
        let d = self.layout.d_model;
        assert_eq!(k.len(), d, "key row width mismatch");
        assert_eq!(v.len(), d, "value row width mismatch");
        let (page, off) = self.offsets(t, layer);
        match self.page_mut(page) {
            KvPage::F32(p) => {
                p[off..off + d].copy_from_slice(k);
                p[off + d..off + 2 * d].copy_from_slice(v);
            }
            KvPage::Bytes(p) => {
                e4m3::encode_slice(k, &mut p[off..off + d]);
                e4m3::encode_slice(v, &mut p[off + d..off + 2 * d]);
            }
        }
    }

    /// Key row of token `t` at `layer` as f32: a zero-copy borrow for
    /// `f32` storage, or an E4M3 decode into `buf[..d_model]` (which must
    /// be at least `d_model` long). The attention loops pass a per-row
    /// scratch buffer so each cached row is decoded at most once per use.
    #[inline]
    pub fn k_row<'a>(&'a self, t: usize, layer: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match self.pages[page].as_ref() {
            KvPage::F32(p) => &p[off..off + d],
            KvPage::Bytes(p) => {
                e4m3::decode_slice(&p[off..off + d], &mut buf[..d]);
                &buf[..d]
            }
        }
    }

    /// Value row of token `t` at `layer` as f32 — same contract as
    /// [`Self::k_row`].
    #[inline]
    pub fn v_row<'a>(&'a self, t: usize, layer: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match self.pages[page].as_ref() {
            KvPage::F32(p) => &p[off + d..off + 2 * d],
            KvPage::Bytes(p) => {
                e4m3::decode_slice(&p[off + d..off + 2 * d], &mut buf[..d]);
                &buf[..d]
            }
        }
    }

    /// Cached (post-RoPE) key of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — quantized rows have no borrowable f32 view;
    /// use [`Self::k_row`] with a scratch buffer instead.
    #[inline]
    pub fn k(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match self.pages[page].as_ref() {
            KvPage::F32(p) => &p[off..off + d],
            KvPage::Bytes(_) => panic!("KvSeq::k needs f32 kv storage; use k_row"),
        }
    }

    /// Cached value of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — use [`Self::v_row`] instead.
    #[inline]
    pub fn v(&self, t: usize, layer: usize) -> &[f32] {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match self.pages[page].as_ref() {
            KvPage::F32(p) => &p[off + d..off + 2 * d],
            KvPage::Bytes(_) => panic!("KvSeq::v needs f32 kv storage; use v_row"),
        }
    }

    /// Mutable key/value buffers of token `t` at `layer`.
    ///
    /// # Panics
    /// On non-`f32` storage — quantized writes must re-encode whole rows;
    /// use [`Self::store_kv`] instead. Also panics when the page holding
    /// token `t` is shared (refcount > 1).
    #[inline]
    pub fn kv_mut(&mut self, t: usize, layer: usize) -> (&mut [f32], &mut [f32]) {
        let d = self.layout.d_model;
        let (page, off) = self.offsets(t, layer);
        match self.page_mut(page) {
            KvPage::F32(p) => p[off..off + 2 * d].split_at_mut(d),
            KvPage::Bytes(_) => panic!("KvSeq::kv_mut needs f32 kv storage; use store_kv"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 2, d_model: 8, page_tokens: 4, format: KvFormat::F32 }
    }

    #[test]
    fn layout_sizes() {
        let l = layout();
        assert_eq!(l.token_floats(), 32);
        assert_eq!(l.page_floats(), 128);
        assert_eq!(l.page_bytes(), 512);
        let q = KvLayout { format: KvFormat::E4m3, ..l };
        assert_eq!(q.page_floats(), 128);
        assert_eq!(q.page_bytes(), 128, "e4m3 pages are 4x smaller");
    }

    #[test]
    fn format_names_parse() {
        for f in [KvFormat::F32, KvFormat::E4m3] {
            assert_eq!(KvFormat::parse(f.name()), Some(f));
        }
        assert_eq!(KvFormat::parse("E4M3"), Some(KvFormat::E4m3));
        assert_eq!(KvFormat::parse("fp8"), Some(KvFormat::E4m3));
        assert_eq!(KvFormat::parse("f16"), None);
    }

    #[test]
    fn push_write_read_roundtrip_across_pages() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        // 10 tokens spans 3 pages (4 tokens each)
        for t in 0..10 {
            seq.push(&mut pool).unwrap();
            for layer in 0..l.n_layers {
                let (k, v) = seq.kv_mut(t, layer);
                for (i, x) in k.iter_mut().enumerate() {
                    *x = (t * 100 + layer * 10 + i) as f32;
                }
                for (i, x) in v.iter_mut().enumerate() {
                    *x = -((t * 100 + layer * 10 + i) as f32);
                }
            }
        }
        assert_eq!(seq.len(), 10);
        assert_eq!(seq.n_pages(), 3);
        assert_eq!(pool.outstanding(), 3);
        let mut buf = vec![0.0f32; l.d_model];
        for t in 0..10 {
            for layer in 0..l.n_layers {
                let k = seq.k(t, layer);
                let v = seq.v(t, layer);
                for i in 0..l.d_model {
                    assert_eq!(k[i], (t * 100 + layer * 10 + i) as f32);
                    assert_eq!(v[i], -((t * 100 + layer * 10 + i) as f32));
                }
                // the row views agree bitwise with the borrows on f32
                let kr: Vec<f32> = seq.k_row(t, layer, &mut buf).to_vec();
                assert_eq!(kr, seq.k(t, layer));
                let vr: Vec<f32> = seq.v_row(t, layer, &mut buf).to_vec();
                assert_eq!(vr, seq.v(t, layer));
            }
        }
        seq.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.free_pages(), 3);
    }

    #[test]
    fn e4m3_store_read_roundtrips_through_codec() {
        let l = KvLayout { format: KvFormat::E4m3, ..layout() };
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        let d = l.d_model;
        // values spanning subnormal, normal, negative, and saturating range
        let mk = |t: usize, layer: usize, i: usize, sign: f32| {
            sign * (0.001 + (t * 37 + layer * 11 + i * 3) as f32 * 1.7)
        };
        for t in 0..9 {
            seq.push(&mut pool).unwrap();
            for layer in 0..l.n_layers {
                let k: Vec<f32> = (0..d).map(|i| mk(t, layer, i, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|i| mk(t, layer, i, -1.0)).collect();
                seq.store_kv(t, layer, &k, &v);
            }
        }
        assert_eq!(seq.n_pages(), 3);
        let mut buf = vec![0.0f32; d];
        for t in 0..9 {
            for layer in 0..l.n_layers {
                for i in 0..d {
                    let want_k = e4m3::roundtrip(mk(t, layer, i, 1.0).min(e4m3::E4M3_MAX));
                    let got_k = seq.k_row(t, layer, &mut buf)[i];
                    assert_eq!(got_k.to_bits(), want_k.to_bits(), "k t={t} l={layer} i={i}");
                    let want_v =
                        e4m3::roundtrip(mk(t, layer, i, -1.0).max(-e4m3::E4M3_MAX));
                    let got_v = seq.v_row(t, layer, &mut buf)[i];
                    assert_eq!(got_v.to_bits(), want_v.to_bits(), "v t={t} l={layer} i={i}");
                }
            }
        }
        seq.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    #[should_panic(expected = "f32 kv storage")]
    fn borrow_views_reject_quantized_storage() {
        let l = KvLayout { format: KvFormat::E4m3, ..layout() };
        let mut pool = KvPool::unbounded(l);
        let mut seq = KvSeq::new(l);
        seq.push(&mut pool).unwrap();
        let _ = seq.k(0, 0);
    }

    #[test]
    fn reserve_matches_pushes_and_is_atomic() {
        let l = layout();
        // reserve(n) leaves the same geometry as n pushes
        let mut pool = KvPool::unbounded(l);
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 10).unwrap();
        let mut b = KvSeq::new(l);
        for _ in 0..10 {
            b.push(&mut pool).unwrap();
        }
        assert_eq!((a.len(), a.n_pages()), (b.len(), b.n_pages()));
        // reserved slots are writable/readable immediately
        let (k, _) = a.kv_mut(9, 1);
        k[0] = 7.0;
        assert_eq!(a.k(9, 1)[0], 7.0);
        // growing an existing sequence only takes the missing pages
        a.reserve(&mut pool, 2).unwrap();
        assert_eq!((a.len(), a.n_pages()), (12, 3));
        a.clear(&mut pool);
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);

        // all-or-nothing on exhaustion: nothing taken, nothing mutated
        let mut small = KvPool::new(l, 2);
        let mut c = KvSeq::new(l);
        c.reserve(&mut small, 4).unwrap(); // exactly one page
        let err = c.reserve(&mut small, 8).unwrap_err(); // needs 2 more, cap allows 1
        assert!(err.downcast_ref::<KvExhausted>().is_some(), "{err}");
        assert_eq!((c.len(), c.n_pages()), (4, 1), "failed reserve mutated the sequence");
        assert_eq!(small.outstanding(), 1, "failed reserve leaked pages");
        c.clear(&mut small);
    }

    #[test]
    fn pool_reuses_freed_pages() {
        let l = layout();
        let mut pool = KvPool::new(l, 4);
        let page = pool.take().unwrap();
        let ptr = match &page {
            KvPage::F32(p) => p.as_ptr(),
            KvPage::Bytes(_) => unreachable!("f32 pool handed out a byte page"),
        };
        pool.put(page);
        assert_eq!(pool.outstanding(), 0);
        // the very same buffer comes back (LIFO reuse), zeroed
        let page = pool.take().unwrap();
        match &page {
            KvPage::F32(p) => {
                assert_eq!(p.as_ptr(), ptr);
                assert!(p.iter().all(|&x| x == 0.0));
            }
            KvPage::Bytes(_) => unreachable!(),
        }
        pool.put(page);
    }

    #[test]
    fn pool_capacity_rejection_and_recovery() {
        let l = layout();
        let mut pool = KvPool::new(l, 2);
        let mut a = KvSeq::new(l);
        // 2 pages worth of tokens fit; the 9th token needs a 3rd page
        for _ in 0..8 {
            a.push(&mut pool).unwrap();
        }
        assert_eq!(pool.outstanding(), 2);
        let err = a.push(&mut pool).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // the typed error survives downcasting (the backend's fallback key)
        let typed = err.downcast_ref::<KvExhausted>().expect("typed exhaustion error");
        assert_eq!(typed.outstanding, 2);
        // a failed push leaves the sequence usable and consistent
        assert_eq!(a.len(), 8);
        // freeing makes capacity available again
        a.clear(&mut pool);
        let mut b = KvSeq::new(l);
        for _ in 0..8 {
            b.push(&mut pool).unwrap();
        }
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn shared_pages_release_exactly_once() {
        let l = layout();
        let mut pool = KvPool::new(l, 8);
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 8).unwrap(); // two full pages
        for t in 0..8 {
            for layer in 0..l.n_layers {
                let (k, _) = a.kv_mut(t, layer);
                k[0] = (t * 10 + layer) as f32;
            }
        }
        // b reuses a's prompt pages without touching the pool
        let mut b = KvSeq::new(l);
        b.attach(a.page_handle(0));
        b.attach(a.page_handle(1));
        assert_eq!(b.len(), 8);
        assert_eq!(b.n_pages(), 2);
        assert_eq!(pool.outstanding(), 2, "attach must not take new pages");
        assert_eq!(a.page_refs(0), 2);
        // shared reads see the same bytes through either sequence
        for t in 0..8 {
            assert_eq!(a.k(t, 1)[0], b.k(t, 1)[0]);
        }
        // release in either order: the buffer is freed exactly once, on
        // the LAST release
        a.clear(&mut pool);
        assert_eq!(pool.outstanding(), 2, "pages freed while b still holds them");
        assert_eq!(pool.free_pages(), 0);
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.free_pages(), 2);
    }

    #[test]
    fn shared_tail_page_is_copied_on_write() {
        let l = layout();
        let mut pool = KvPool::new(l, 8);
        let mut a = KvSeq::new(l);
        // 6 tokens: page 0 full, page 1 partial (2 of 4 slots)
        for t in 0..6 {
            a.push(&mut pool).unwrap();
            let (k, _) = a.kv_mut(t, 0);
            k[0] = t as f32;
        }
        // a stray shared handle on the PARTIAL tail page (the backend
        // never does this; the API guards it anyway)
        let held = a.page_handle(1);
        assert_eq!(a.page_refs(1), 2);
        // the next push copies the tail before writing into it
        a.push(&mut pool).unwrap();
        let (k, _) = a.kv_mut(6, 0);
        k[0] = 6.0;
        assert_eq!(a.page_refs(1), 1, "tail still shared after CoW push");
        // the copy kept the old contents; the shared original is untouched
        assert_eq!(a.k(4, 0)[0], 4.0);
        assert_eq!(a.k(5, 0)[0], 5.0);
        match held.as_ref() {
            KvPage::F32(p) => {
                // token 6 is slot 2 of the page; the held page never saw it
                assert_eq!(p[2 * l.token_floats()], 0.0);
            }
            KvPage::Bytes(_) => unreachable!(),
        }
        // 3 physical pages: a's page 0, a's CoW tail, the held original
        assert_eq!(pool.outstanding(), 3);
        pool.release(held);
        a.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    #[should_panic(expected = "shared kv page")]
    fn write_to_shared_page_panics() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 4).unwrap();
        let _held = a.page_handle(0);
        let row = vec![0.0f32; l.d_model];
        a.store_kv(3, 0, &row, &row);
    }

    #[test]
    fn truncate_releases_whole_pages_and_keeps_contents() {
        let l = layout();
        let mut pool = KvPool::new(l, 8);
        let mut a = KvSeq::new(l);
        // 10 tokens over 3 pages; tag each token so survivors are checkable
        for t in 0..10 {
            a.push(&mut pool).unwrap();
            let (k, _) = a.kv_mut(t, 0);
            k[0] = t as f32;
        }
        assert_eq!((a.len(), a.n_pages(), pool.outstanding()), (10, 3, 3));
        // keep >= len is a no-op
        assert_eq!(a.truncate(&mut pool, 10), 10);
        assert_eq!(a.truncate(&mut pool, 99), 10);
        assert_eq!((a.len(), a.n_pages()), (10, 3));
        // mid-page boundary on an exclusively-owned tail: length shrinks
        // exactly, the partial page stays
        assert_eq!(a.truncate(&mut pool, 6), 6);
        assert_eq!((a.len(), a.n_pages(), pool.outstanding()), (6, 2, 2));
        for t in 0..6 {
            assert_eq!(a.k(t, 0)[0], t as f32, "surviving token {t} lost its row");
        }
        // regrowing reuses the freed capacity and writes fresh slots
        a.push(&mut pool).unwrap();
        let (k, _) = a.kv_mut(6, 0);
        k[0] = 60.0;
        assert_eq!(a.k(6, 0)[0], 60.0);
        assert_eq!(a.len(), 7);
        // page-aligned truncate, then to zero
        assert_eq!(a.truncate(&mut pool, 4), 4);
        assert_eq!((a.len(), a.n_pages(), pool.outstanding()), (4, 1, 1));
        assert_eq!(a.truncate(&mut pool, 0), 0);
        assert_eq!((a.len(), a.n_pages(), pool.outstanding()), (0, 0, 0));
    }

    #[test]
    fn truncate_into_shared_page_drops_to_page_boundary() {
        let l = layout();
        let mut pool = KvPool::new(l, 8);
        let mut a = KvSeq::new(l);
        a.reserve(&mut pool, 8).unwrap(); // two full pages
        let mut b = KvSeq::new(l);
        b.attach(a.page_handle(0));
        b.attach(a.page_handle(1));
        assert_eq!(pool.outstanding(), 2);
        // keep=6 lands inside b's SHARED page 1: the shared handle cannot
        // be rewritten, so b falls back to the 4-token page boundary
        assert_eq!(b.truncate(&mut pool, 6), 4);
        assert_eq!((b.len(), b.n_pages()), (4, 1));
        assert_eq!(a.page_refs(1), 1, "b still holds the truncated shared page");
        assert_eq!(pool.outstanding(), 2, "a's handles keep both pages alive");
        // a page-aligned keep on a shared page needs no drop at all
        assert_eq!(b.truncate(&mut pool, 4), 4);
        assert_eq!(b.n_pages(), 1);
        a.clear(&mut pool);
        assert_eq!(pool.outstanding(), 1, "b's attached page must survive a's clear");
        b.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn pool_high_water_tracks_peak() {
        let l = layout();
        let mut pool = KvPool::new(l, 8);
        assert_eq!(pool.high_water(), 0);
        let p1 = pool.take().unwrap();
        let p2 = pool.take().unwrap();
        assert_eq!(pool.high_water(), 2);
        pool.put(p1);
        pool.put(p2);
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.high_water(), 2, "high water must not fall with frees");
        let p3 = pool.take().unwrap();
        assert_eq!(pool.high_water(), 2, "re-take below the peak keeps the peak");
        pool.put(p3);
    }
}
