//! Token-prefix trie over full KV pages — the shared-prefix cache.
//!
//! Serving traffic is dominated by requests that share a prompt prefix
//! (system prompts, few-shot templates). Prefilling recomputes the same
//! K/V rows for every one of them; this module lets a request *attach*
//! to pages another request already filled and prefill only its suffix.
//!
//! The trie is keyed by page-sized token chunks: a node at depth `d`
//! holds the page caching tokens `[d*page_tokens, (d+1)*page_tokens)` of
//! every prompt whose first `(d+1)*page_tokens` tokens match the path to
//! that node. Only **full** pages are stored — a partial tail page's
//! contents depend on how many tokens follow, so it stays exclusive to
//! its slot (which is also what keeps every KV write refcount-1; see
//! [`super::kv`]).
//!
//! One trie exists per [`super::NativeBackend`], so the (model preset,
//! activation-quant mode, KV format, page geometry) part of the cache
//! key is implicit — pages from one backend are never visible to
//! another. Within a backend the token path alone determines the stored
//! bytes: the backend computes K/V rows from `(token prefix, absolute
//! positions from 0)` deterministically, and a trie path of length `n`
//! chunks always means positions `0..n*page_tokens`. That is why a
//! cache-hit request's logits are **bit-identical** to a cold run: both
//! paths read attention inputs back from stored pages, and the stored
//! bytes are the same either way.
//!
//! Concurrency/locking: the trie lives behind a `Mutex` next to the
//! backend's page pool. Code that holds both locks must take the trie
//! lock **first**, then the pool lock (eviction does this); the reverse
//! order would deadlock against it.
//!
//! Eviction is LRU over *leaf* pages only — an interior page can never
//! be evicted before its children, because a child page's tokens are
//! meaningless without every page of its prefix. `last_used` is a
//! monotonic tick bumped on every lookup touch, and a parent is at least
//! as recent as its most-recent descendant (lookups touch whole paths),
//! so evicting the stalest leaf is exactly LRU over reusable prefixes.

use std::collections::HashMap;
use std::sync::Arc;

use super::kv::{KvPage, KvPool};

/// Counters the serve layer surfaces as the prefix-cache hit rate.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStats {
    /// Lookups performed (one per cold slot admission).
    pub lookups: u64,
    /// Lookups that attached at least one cached page.
    pub hits: u64,
    /// Prompt tokens served from cached pages instead of prefill.
    pub hit_tokens: u64,
    /// Full pages currently held by the trie.
    pub stored_pages: usize,
}

struct Node {
    page: Arc<KvPage>,
    last_used: u64,
    children: HashMap<Box<[i32]>, Node>,
}

/// The shared-prefix page trie. See the module docs for the layout and
/// the bit-exactness argument.
pub struct PrefixCache {
    page_tokens: usize,
    root: HashMap<Box<[i32]>, Node>,
    tick: u64,
    lookups: u64,
    hits: u64,
    hit_tokens: u64,
    stored_pages: usize,
}

impl PrefixCache {
    /// An empty trie for pages holding `page_tokens` tokens each.
    pub fn new(page_tokens: usize) -> PrefixCache {
        PrefixCache {
            page_tokens: page_tokens.max(1),
            root: HashMap::new(),
            tick: 0,
            lookups: 0,
            hits: 0,
            hit_tokens: 0,
            stored_pages: 0,
        }
    }

    /// Tokens per stored page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Full pages currently stored.
    pub fn len(&self) -> usize {
        self.stored_pages
    }

    /// True when no pages are stored.
    pub fn is_empty(&self) -> bool {
        self.stored_pages == 0
    }

    /// Walk the trie along `tokens` and return handles to the pages of
    /// the longest cached full-page prefix (possibly empty). Touches
    /// every node on the path for LRU. The caller attaches the pages to
    /// a [`super::kv::KvSeq`] and must eventually return each handle
    /// through [`KvPool::release`].
    pub fn lookup(&mut self, tokens: &[i32]) -> Vec<Arc<KvPage>> {
        self.tick += 1;
        let tick = self.tick;
        self.lookups += 1;
        let mut out = Vec::new();
        let mut map = &mut self.root;
        for chunk in tokens.chunks_exact(self.page_tokens) {
            match map.get_mut(chunk) {
                Some(node) => {
                    node.last_used = tick;
                    out.push(Arc::clone(&node.page));
                    map = &mut node.children;
                }
                None => break,
            }
        }
        if !out.is_empty() {
            self.hits += 1;
            self.hit_tokens += (out.len() * self.page_tokens) as u64;
        }
        out
    }

    /// Store the pages caching `tokens` (whose length must be a multiple
    /// of `page_tokens`; `pages[i]` caches chunk `i`). First writer wins:
    /// chunks already present keep their existing page — the bytes are
    /// identical by the determinism argument in the module docs, and
    /// keeping the old page preserves refcounts already handed out.
    pub fn publish(&mut self, tokens: &[i32], pages: &[Arc<KvPage>]) {
        debug_assert_eq!(tokens.len(), pages.len() * self.page_tokens, "ragged publish");
        self.tick += 1;
        let tick = self.tick;
        let mut stored = 0usize;
        let mut map = &mut self.root;
        for (chunk, page) in tokens.chunks_exact(self.page_tokens).zip(pages) {
            let node = map.entry(chunk.into()).or_insert_with(|| {
                stored += 1;
                Node { page: Arc::clone(page), last_used: tick, children: HashMap::new() }
            });
            node.last_used = tick;
            map = &mut node.children;
        }
        self.stored_pages += stored;
    }

    /// Evict the least-recently-used **leaf** page, releasing its handle
    /// into `pool` (the buffer is recycled immediately if no sequence
    /// still references it). Returns `false` when the trie is empty.
    /// Callers holding the pool lock must have taken the trie lock
    /// first.
    pub fn evict_lru(&mut self, pool: &mut KvPool) -> bool {
        match evict_from(&mut self.root) {
            Some(page) => {
                self.stored_pages -= 1;
                pool.release(page);
                true
            }
            None => false,
        }
    }

    /// Release every stored page into `pool` and empty the trie. Hit/miss
    /// counters are kept (they describe traffic, not contents).
    pub fn clear(&mut self, pool: &mut KvPool) {
        let mut stack: Vec<Node> = self.root.drain().map(|(_, n)| n).collect();
        while let Some(mut n) = stack.pop() {
            stack.extend(n.children.drain().map(|(_, c)| c));
            pool.release(n.page);
        }
        self.stored_pages = 0;
    }

    /// Current counters.
    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            lookups: self.lookups,
            hits: self.hits,
            hit_tokens: self.hit_tokens,
            stored_pages: self.stored_pages,
        }
    }
}

/// Oldest `last_used` among the leaves under `n` (a leaf is its own
/// bound). Interior ticks are ignored: only leaves are evictable.
fn oldest_leaf(n: &Node) -> u64 {
    if n.children.is_empty() {
        n.last_used
    } else {
        n.children.values().map(oldest_leaf).min().expect("non-empty children")
    }
}

/// Descend toward and remove the leaf with the oldest `last_used`,
/// returning its page handle.
fn evict_from(map: &mut HashMap<Box<[i32]>, Node>) -> Option<Arc<KvPage>> {
    let key = map
        .iter()
        .map(|(k, n)| (oldest_leaf(n), k))
        .min_by_key(|(t, _)| *t)
        .map(|(_, k)| k.clone())?;
    let node = map.get_mut(&key).expect("key just selected");
    if node.children.is_empty() {
        Some(map.remove(&key).expect("key just selected").page)
    } else {
        evict_from(&mut node.children)
    }
}

#[cfg(test)]
mod tests {
    use super::super::kv::{KvFormat, KvLayout, KvSeq};
    use super::*;

    fn layout() -> KvLayout {
        KvLayout { n_layers: 1, d_model: 4, page_tokens: 4, format: KvFormat::F32 }
    }

    /// Build `n_pages` full pages in a throwaway sequence, marking each
    /// page's first element with `tag` so tests can tell pages apart.
    fn make_pages(pool: &mut KvPool, tag: f32, n_pages: usize) -> Vec<Arc<KvPage>> {
        let l = layout();
        let mut seq = KvSeq::new(l);
        seq.reserve(pool, n_pages * l.page_tokens).unwrap();
        for p in 0..n_pages {
            let (k, _) = seq.kv_mut(p * l.page_tokens, 0);
            k[0] = tag + p as f32;
        }
        let handles: Vec<_> = (0..n_pages).map(|i| seq.page_handle(i)).collect();
        seq.clear(pool);
        handles
    }

    fn first_elem(page: &KvPage) -> f32 {
        match page {
            KvPage::F32(p) => p[0],
            KvPage::Bytes(_) => unreachable!("f32 tests"),
        }
    }

    #[test]
    fn publish_then_lookup_returns_longest_prefix() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut trie = PrefixCache::new(l.page_tokens);
        let toks: Vec<i32> = (0..12).collect(); // 3 full pages
        let pages = make_pages(&mut pool, 100.0, 3);
        trie.publish(&toks, &pages);
        assert_eq!(trie.len(), 3);

        // exact prompt: all 3 pages, in order
        let hit = trie.lookup(&toks);
        assert_eq!(hit.len(), 3);
        for (i, p) in hit.iter().enumerate() {
            assert_eq!(first_elem(p), 100.0 + i as f32);
            pool.release(Arc::clone(p));
        }
        drop(hit);

        // longer prompt sharing 2 full chunks + a diverging 3rd
        let mut longer: Vec<i32> = (0..8).collect();
        longer.extend_from_slice(&[99, 98, 97, 96, 95]);
        let hit = trie.lookup(&longer);
        assert_eq!(hit.len(), 2, "divergent chunk must stop the walk");
        for p in hit {
            pool.release(p);
        }

        // shorter-than-a-page prompt: lookup counts a miss
        let hit = trie.lookup(&toks[..3]);
        assert!(hit.is_empty());
        let s = trie.stats();
        assert_eq!(s.lookups, 3);
        assert_eq!(s.hits, 2);
        assert_eq!(s.hit_tokens, (3 + 2) * 4);

        trie.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn publish_is_first_writer_wins() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut trie = PrefixCache::new(l.page_tokens);
        let toks: Vec<i32> = (0..4).collect();
        let first = make_pages(&mut pool, 1.0, 1);
        let second = make_pages(&mut pool, 2.0, 1);
        trie.publish(&toks, &first);
        trie.publish(&toks, &second);
        assert_eq!(trie.len(), 1, "re-publish must not duplicate nodes");
        let hit = trie.lookup(&toks);
        assert_eq!(first_elem(&hit[0]), 1.0, "first writer's page must survive");
        pool.release(hit.into_iter().next().unwrap());
        // the losing publisher's handles still release cleanly
        for p in second {
            pool.release(p);
        }
        trie.clear(&mut pool);
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn evict_lru_takes_stalest_leaf_first() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut trie = PrefixCache::new(l.page_tokens);
        // two branches under a shared first page:
        //   [0..4) -> [4..8)   (branch A)
        //   [0..4) -> [20..24) (branch B)
        let shared: Vec<i32> = (0..4).collect();
        let mut a = shared.clone();
        a.extend(4..8);
        let mut b = shared.clone();
        b.extend(20..24);
        trie.publish(&a, &make_pages(&mut pool, 10.0, 2));
        trie.publish(&b, &make_pages(&mut pool, 20.0, 2));
        assert_eq!(trie.len(), 3, "shared first chunk stored once");

        // touch branch B so branch A's leaf is stalest
        for p in trie.lookup(&b) {
            pool.release(p);
        }
        assert!(trie.evict_lru(&mut pool));
        assert_eq!(trie.len(), 2);
        let hit = trie.lookup(&a);
        assert_eq!(hit.len(), 1, "branch A's leaf gone, shared root kept");
        for p in hit {
            pool.release(p);
        }
        // next eviction takes B's leaf (root has a child until then)
        assert!(trie.evict_lru(&mut pool));
        assert!(trie.evict_lru(&mut pool));
        assert!(!trie.evict_lru(&mut pool), "empty trie has nothing to evict");
        assert_eq!(trie.len(), 0);
        assert_eq!(pool.outstanding(), 0, "evicted pages must return to the pool");
    }

    #[test]
    fn clear_releases_every_page() {
        let l = layout();
        let mut pool = KvPool::unbounded(l);
        let mut trie = PrefixCache::new(l.page_tokens);
        let toks: Vec<i32> = (0..16).collect();
        trie.publish(&toks, &make_pages(&mut pool, 0.0, 4));
        assert_eq!(pool.outstanding(), 4);
        trie.clear(&mut pool);
        assert!(trie.is_empty());
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.free_pages(), 4);
    }
}
