//! Native model presets: the rust-side mirror of
//! `python/compile/configs.py`, so a model can be stood up — weights
//! initialized, quantized, and served — on a machine with **no
//! `artifacts/` directory and no XLA backend at all**.
//!
//! The weight layout (names, shapes, init specs, quantized flags, and
//! crucially the *order*, which seeds the per-weight init RNG) must stay
//! byte-identical to `configs.weight_specs`; the artifact-gated parity
//! test in `tests/integration_serve.rs` cross-checks the two whenever a
//! real manifest is present.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::formats::codec::{codec_for, rtn_decisions, FormatKind, QuantTensor};
use crate::runtime::{manifest::Init, Manifest, ModelConfig, QLinear, WeightSpec};
use crate::train::{ParamStore, QuantParamStore};
use crate::util::threads::{self, par_map};

/// Round `x` up to a multiple of `m` (mlp sizing, mirrors configs.py).
fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Build a [`ModelConfig`] with the derived fields (`head_dim`,
/// `mlp_hidden`) computed the way `configs.ModelConfig` computes them.
pub fn native_config(
    name: &str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    seq_len: usize,
) -> Result<ModelConfig> {
    if n_heads == 0 || d_model % n_heads != 0 {
        bail!("d_model {d_model} not divisible by n_heads {n_heads}");
    }
    let head_dim = d_model / n_heads;
    if head_dim % 2 != 0 {
        bail!("rope needs an even head_dim, got {head_dim}");
    }
    let block = 16;
    let mlp_hidden = round_up(d_model * 8 / 3, 32);
    if d_model % block != 0 || mlp_hidden % block != 0 {
        bail!("dims must tile the NVFP4 block size {block}");
    }
    Ok(ModelConfig {
        name: name.to_string(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        seq_len,
        block,
        mlp_hidden,
        head_dim,
        train_batch: 8,
        eval_batch: 8,
        stage1_rows: 512,
        stage2_batch: 8,
    })
}

/// The named presets from `configs.CONFIGS` (nano / tiny / small / med),
/// plus the serving-only `*-draft` companions: each shares its target's
/// vocabulary (a speculative draft must propose valid target token ids)
/// at a fraction of the depth/width, sized for `--draft-model`. The
/// draft presets have no python mirror — they exist for the native
/// serving path only.
pub fn preset_config(preset: &str) -> Result<ModelConfig> {
    let mut cfg = match preset {
        "nano" => native_config("nano", 256, 64, 2, 2, 64)?,
        "tiny" => native_config("tiny", 512, 128, 4, 4, 128)?,
        "small" => native_config("small", 1024, 192, 6, 6, 128)?,
        "med" => native_config("med", 4096, 384, 8, 8, 256)?,
        "tiny-draft" => native_config("tiny-draft", 512, 64, 2, 2, 128)?,
        "small-draft" => native_config("small-draft", 1024, 64, 2, 2, 128)?,
        "med-draft" => native_config("med-draft", 4096, 96, 2, 2, 256)?,
        other => bail!(
            "unknown model preset '{other}' \
             (nano|tiny|small|med|tiny-draft|small-draft|med-draft)"
        ),
    };
    if preset == "nano" {
        cfg.train_batch = 4;
        cfg.eval_batch = 4;
        cfg.stage1_rows = 128;
        cfg.stage2_batch = 4;
    }
    Ok(cfg)
}

/// The canonical weight layout for a config — same names, shapes, init
/// specs, quantized flags, and order as `configs.weight_specs`.
pub fn weight_specs(cfg: &ModelConfig) -> Vec<WeightSpec> {
    let (l, d, h, v) = (cfg.n_layers, cfg.d_model, cfg.mlp_hidden, cfg.vocab);
    let spec = |name: &str, shape: Vec<usize>, init: Init, quantized: bool| WeightSpec {
        name: name.to_string(),
        shape,
        init,
        quantized,
    };
    vec![
        spec("tok_emb", vec![v, d], Init::Normal(0.02), false),
        spec("layers.attn_norm", vec![l, d], Init::Ones, false),
        spec("layers.wq", vec![l, d, d], Init::Normal(0.02), true),
        spec("layers.wk", vec![l, d, d], Init::Normal(0.02), true),
        spec("layers.wv", vec![l, d, d], Init::Normal(0.02), true),
        spec("layers.wo", vec![l, d, d], Init::NormalScaled(0.02), true),
        spec("layers.mlp_norm", vec![l, d], Init::Ones, false),
        spec("layers.w_gate", vec![l, d, h], Init::Normal(0.02), true),
        spec("layers.w_up", vec![l, d, h], Init::Normal(0.02), true),
        spec("layers.w_down", vec![l, h, d], Init::NormalScaled(0.02), true),
        spec("out_norm", vec![d], Init::Ones, false),
        spec("lm_head", vec![d, v], Init::Normal(0.02), false),
    ]
}

/// Assemble a [`Manifest`] for a config without touching disk. The
/// artifact table is empty — this manifest drives native (pure-rust)
/// inference, never the XLA runtime.
pub fn manifest_from_config(cfg: ModelConfig) -> Manifest {
    let weights = weight_specs(&cfg);
    let ql = |name: &str, capture: &str, k: usize, n: usize| QLinear {
        name: name.to_string(),
        capture: capture.to_string(),
        k,
        n,
    };
    let (d, h) = (cfg.d_model, cfg.mlp_hidden);
    let qlinears = vec![
        ql("layers.wq", "attn_in", d, d),
        ql("layers.wk", "attn_in", d, d),
        ql("layers.wv", "attn_in", d, d),
        ql("layers.wo", "attn_o_in", d, d),
        ql("layers.w_gate", "mlp_in", d, h),
        ql("layers.w_up", "mlp_in", d, h),
        ql("layers.w_down", "mlp_down_in", h, d),
    ];
    let captures =
        ["attn_in", "attn_o_in", "mlp_in", "mlp_down_in"].map(String::from).to_vec();
    Manifest { config: cfg, weights, qlinears, captures, artifacts: BTreeMap::new() }
}

/// One-call preset manifest: `native_manifest("tiny")` is everything the
/// native serving path needs where the XLA path would load
/// `artifacts/tiny/manifest.json`.
pub fn native_manifest(preset: &str) -> Result<Manifest> {
    Ok(manifest_from_config(preset_config(preset)?))
}

/// Check that `draft` can propose tokens for `target` — speculative
/// decoding requires one shared vocabulary (every draft proposal must be
/// a valid target token id) and a draft window that can hold the
/// target's sequences. Called at CLI parse time so `--draft-model nano
/// --model tiny` (vocab 256 vs 512) fails before any weights are built;
/// `ModelRegistry::new` re-checks vocab on the built backends as the
/// backstop.
pub fn check_draft_compat(target: &ModelConfig, draft: &ModelConfig) -> Result<()> {
    if draft.vocab != target.vocab {
        bail!(
            "draft preset '{}' (vocab {}) cannot speculate for '{}' (vocab {}); \
             draft and target must share one vocabulary",
            draft.name,
            draft.vocab,
            target.name,
            target.vocab
        );
    }
    if draft.seq_len < target.seq_len {
        bail!(
            "draft preset '{}' window {} is shorter than target '{}' window {}; \
             speculation would silently stop at the draft's horizon",
            draft.name,
            draft.seq_len,
            target.name,
            target.seq_len
        );
    }
    Ok(())
}

/// RTN-quantize every `quantized` weight of `fp` through `format`'s
/// codec, layer stacks in parallel, producing the packed store the
/// native backend serves from. Pure rust — no artifacts, no calibration.
pub fn quantize_store(
    manifest: &Manifest,
    fp: &ParamStore,
    format: FormatKind,
) -> Result<QuantParamStore> {
    let names: Vec<String> =
        manifest.weights.iter().filter(|w| w.quantized).map(|w| w.name.clone()).collect();
    let codec = codec_for(format);
    let pairs: Vec<Result<(String, QuantTensor)>> =
        par_map(names, threads::default_workers(), |name| {
            let w = fp.get(&name)?;
            let p = codec.prepare(w);
            let q = codec.encode(w, &p, &rtn_decisions(&p));
            Ok((name, q))
        });
    let mut packed = BTreeMap::new();
    for pair in pairs {
        let (name, q) = pair?;
        packed.insert(name, q);
    }
    Ok(QuantParamStore::from_store(fp, packed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_mirror_configs_py() {
        let nano = preset_config("nano").unwrap();
        assert_eq!((nano.vocab, nano.d_model, nano.n_layers), (256, 64, 2));
        assert_eq!((nano.n_heads, nano.seq_len, nano.head_dim), (2, 64, 32));
        // mlp_hidden = round_up(64 * 8 / 3, 32) = round_up(170, 32)
        assert_eq!(nano.mlp_hidden, 192);
        assert_eq!((nano.train_batch, nano.stage1_rows), (4, 128));
        let tiny = preset_config("tiny").unwrap();
        assert_eq!(tiny.mlp_hidden, 352); // round_up(341, 32)
        assert_eq!(tiny.train_batch, 8);
        let med = preset_config("med").unwrap();
        assert_eq!((med.d_model, med.seq_len), (384, 256));
        assert!(preset_config("huge").is_err());
    }

    #[test]
    fn manifest_layout_and_init() {
        let m = native_manifest("nano").unwrap();
        assert_eq!(m.weights.len(), 12);
        assert_eq!(m.qlinears.len(), 7);
        assert_eq!(m.captures.len(), 4);
        assert!(m.artifacts.is_empty());
        // order is load-bearing (per-index init seeding)
        let names: Vec<&str> = m.weights.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names[0], "tok_emb");
        assert_eq!(names[2], "layers.wq");
        assert_eq!(names[11], "lm_head");
        // init works and respects the layout
        let fp = ParamStore::init(&m, 42);
        fp.check_layout(&m).unwrap();
        assert_eq!(fp.get("layers.wq").unwrap().shape, vec![2, 64, 64]);
        // deterministic
        let fp2 = ParamStore::init(&m, 42);
        assert_eq!(
            fp.get("lm_head").unwrap().data,
            fp2.get("lm_head").unwrap().data
        );
    }

    #[test]
    fn quantize_store_packs_the_seven_linears() {
        let m = native_manifest("nano").unwrap();
        let fp = ParamStore::init(&m, 7);
        for format in [FormatKind::Nvfp4, FormatKind::Mxfp4, FormatKind::E2m1] {
            let store = quantize_store(&m, &fp, format).unwrap();
            assert_eq!(store.n_packed(), 7, "{}", format.name());
            assert!(store.packed("layers.wq").is_some());
            assert!(store.packed("tok_emb").is_none());
            assert!(store.packed_payload_bytes() > 0);
            // packed is several times smaller than dense fp32
            assert!(store.packed_payload_bytes() * 4 < store.packed_dense_bytes());
            // dequant passthrough still serves every weight
            assert_eq!(store.get("out_norm").unwrap().shape, vec![64]);
            assert_eq!(store.get("layers.w_down").unwrap().shape, vec![2, 192, 64]);
        }
    }

    #[test]
    fn draft_presets_pair_with_their_targets() {
        for (t, d) in [("tiny", "tiny-draft"), ("small", "small-draft"), ("med", "med-draft")] {
            let target = preset_config(t).unwrap();
            let draft = preset_config(d).unwrap();
            check_draft_compat(&target, &draft).unwrap();
            assert!(
                draft.d_model < target.d_model && draft.n_layers < target.n_layers,
                "draft '{d}' must be cheaper than its target '{t}'"
            );
        }
        // mismatched vocab and short draft windows are rejected
        let tiny = preset_config("tiny").unwrap();
        let nano = preset_config("nano").unwrap();
        assert!(check_draft_compat(&tiny, &nano).unwrap_err().to_string().contains("vocab"));
        let mut short = preset_config("tiny-draft").unwrap();
        short.seq_len = 64;
        assert!(check_draft_compat(&tiny, &short).unwrap_err().to_string().contains("window"));
    }

    #[test]
    fn custom_config_validation() {
        assert!(native_config("x", 64, 30, 1, 4, 8).is_err()); // 30 % 4 != 0
        assert!(native_config("x", 64, 48, 1, 16, 8).is_err()); // head_dim 3 is odd
        let c = native_config("bench", 256, 64, 2, 2, 256).unwrap();
        assert_eq!(c.seq_len, 256);
        assert_eq!(c.head_dim, 32);
    }
}
