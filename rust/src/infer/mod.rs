//! Native quantized inference backend: the full transformer forward pass
//! in pure rust, computing directly on packed [`QuantTensor`] weights.
//!
//! This is the serving path that needs **no artifacts and no XLA
//! backend**: where `serve::RuntimeBackend` executes AOT-lowered graphs
//! through PJRT, [`NativeBackend`] runs the same Llama-style decoder
//! (RoPE + RMSNorm + SwiGLU, mirroring `python/compile/model.py`) with
//! fused nibble-decode GEMM kernels ([`kernels`]) that dequantize
//! NVFP4/MXFP4 blocks on the fly inside the inner loop — the weights
//! stay in the ~4.5-bit packed numerical space end to end, the
//! discipline FAAR argues for.
//!
//! Decode cost: a paged per-slot KV cache ([`kv`]) makes each batched
//! decode step O(window) instead of O(window²) — only the newest token
//! runs through the linear stack; keys (post-RoPE) and values are
//! appended once and reused. Cached and uncached decode are **bit
//! identical**: the cached step replays exactly the float ops the
//! full-window recompute would, so the parity tests assert token
//! equality, not closeness. That invariant holds *per KV format*
//! ([`KvFormat`], `--kv-format`): with `f32` storage rows are cached
//! verbatim, with `e4m3` every row is FP8-quantized on store and decoded
//! on read — cached and uncached still agree bitwise (both quantize the
//! same rows the same way), but `e4m3` logits differ from `f32` logits
//! by a small, tolerance-tested amount (DESIGN.md §12).
//!
//! Payload traffic is amortized across rows (DESIGN.md §11): prompt
//! prefill runs all positions through the seven linears in `[T, ·]`
//! batched form ([`NativeModel::prefill`]), and a batched decode step
//! gathers the active slots into one `[B, ·]` pass per packed layer —
//! both through [`kernels::Linear::matmul`], which reads and
//! LUT-decodes each packed byte once per row tile instead of once per
//! token/slot, with every output row bitwise identical to the matvec
//! it replaces.
//!
//! Module map:
//!
//! * [`preset`] — rust-side mirror of `configs.py` (stand up a model with
//!   no `artifacts/` directory) plus pure-rust RTN quantization
//! * [`kernels`] — fused dequant-GEMM over [`formats::codec::BlockDecode`]
//! * [`ops`] — RMSNorm / RoPE / softmax / SiLU / activation fake-quant
//! * [`kv`] — the paged KV pool and per-slot sequences (refcounted
//!   pages, so prompt prefixes can be shared)
//! * [`prefix`] — the shared-prefix page trie behind `--prefix-cache`:
//!   requests that share a prompt prefix attach to already-filled pages
//!   and prefill only their suffix, with cache-hit logits bit-identical
//!   to a cold run (DESIGN.md §13)
//!
//! See DESIGN.md §9 for the architecture, the slot lifecycle, and the
//! native-vs-XLA parity/tolerance story.
//!
//! [`QuantTensor`]: crate::formats::codec::QuantTensor
//! [`formats::codec::BlockDecode`]: crate::formats::codec::BlockDecode

pub mod kernels;
pub mod kv;
pub mod ops;
pub mod prefix;
pub mod preset;

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

pub use kernels::Linear;
pub use kv::{KvFormat, KvLayout, KvPool, KvSeq};
pub use prefix::{PrefixCache, PrefixStats};
pub use preset::{check_draft_compat, native_manifest, quantize_store};

use crate::runtime::ModelConfig;
use crate::serve::batch::{CacheStats, DecodeSlot, StepBackend};
use crate::tensor::Tensor;
use crate::train::QuantParamStore;
use crate::util::threads;

/// Default cached tokens per KV page — the [`NativeOptions`] default
/// and the scratch pools behind [`NativeModel::logits_window`] /
/// [`NativeModel::prefill`] when no explicit `--kv-page-tokens` /
/// [`NativeOptions::page_tokens`] reaches them.
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// Reusable per-decode buffers: one per in-flight forward, so the hot
/// loop allocates nothing per token.
struct Scratch {
    /// residual stream `[d]`
    x: Vec<f32>,
    /// normed linear input `[d]`
    a: Vec<f32>,
    /// query / key / value projections `[d]`
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// attention mix `[d]`
    attn: Vec<f32>,
    /// projection accumulator `[d]`
    proj: Vec<f32>,
    /// SwiGLU gate / up `[mlp_hidden]`
    g: Vec<f32>,
    u: Vec<f32>,
    /// attention scores `[n_heads, seq_len]` — all heads' score rows for
    /// the two-pass attention sweep (scores for every head, then one
    /// softmax + weighted-sum pass), so each cached K/V row is read (and,
    /// for quantized storage, decoded) once per layer instead of once per
    /// head
    scores: Vec<f32>,
    /// f32 staging row `[d]` for quantized K/V reads ([`KvSeq::k_row`])
    kvbuf: Vec<f32>,
    /// decoded block-scale row for the fused kernels
    scale_row: Vec<f32>,
}

impl Scratch {
    fn new(cfg: &ModelConfig) -> Scratch {
        let (d, h) = (cfg.d_model, cfg.mlp_hidden);
        Scratch {
            x: vec![0.0; d],
            a: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            g: vec![0.0; h],
            u: vec![0.0; h],
            scores: vec![0.0; cfg.n_heads * cfg.seq_len],
            kvbuf: vec![0.0; d],
            scale_row: Vec::new(),
        }
    }
}

/// Reusable buffers for the batched (multi-row) forward passes — the
/// prefill path and the cross-slot batched decode. Sized on first use
/// and grown monotonically in capacity, so steady-state batched decode
/// allocates nothing per step (the [`NativeBackend`] keeps one behind a
/// mutex; prefill catch-up reuses the slot's own copy).
struct RowScratch {
    /// residual stream `[rows, d]`
    x: Vec<f32>,
    /// normed linear inputs `[rows, d]`
    a: Vec<f32>,
    /// projections `[rows, d]`
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    /// SwiGLU gate / up `[rows, mlp_hidden]`
    g: Vec<f32>,
    u: Vec<f32>,
    /// attention scores `[rows, n_heads, seq_len]` — one disjoint chunk
    /// per attention job, so rows can attend in parallel while each
    /// job's two-pass sweep reads every cached K/V row only once
    scores: Vec<f32>,
    /// f32 staging rows `[rows, d]` for quantized K/V reads, one
    /// disjoint row per attention job
    kvbuf: Vec<f32>,
    /// decoded block-scale row for the fused kernels
    scale_row: Vec<f32>,
    /// logits staging `[logit_rows, vocab]`
    logits: Vec<f32>,
}

impl RowScratch {
    fn new() -> RowScratch {
        RowScratch {
            x: Vec::new(),
            a: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            attn: Vec::new(),
            proj: Vec::new(),
            g: Vec::new(),
            u: Vec::new(),
            scores: Vec::new(),
            kvbuf: Vec::new(),
            scale_row: Vec::new(),
            logits: Vec::new(),
        }
    }

    /// Resize every buffer for a `rows`-row pass (capacity only grows).
    fn ensure(&mut self, cfg: &ModelConfig, rows: usize) {
        fn fit(buf: &mut Vec<f32>, len: usize) {
            buf.clear();
            buf.resize(len, 0.0);
        }
        let (d, h) = (cfg.d_model, cfg.mlp_hidden);
        fit(&mut self.x, rows * d);
        fit(&mut self.a, rows * d);
        fit(&mut self.q, rows * d);
        fit(&mut self.k, rows * d);
        fit(&mut self.v, rows * d);
        fit(&mut self.attn, rows * d);
        fit(&mut self.proj, rows * d);
        fit(&mut self.g, rows * h);
        fit(&mut self.u, rows * h);
        fit(&mut self.scores, rows * cfg.n_heads * cfg.seq_len);
        fit(&mut self.kvbuf, rows * d);
    }
}

/// The decoder weights in serving form: quantized linear stacks packed
/// ([`Linear::Packed`]), everything else dense f32, plus precomputed
/// RoPE tables. Cloning is cheap relative to a dense model — the seven
/// linear stacks stay packed.
#[derive(Clone, Debug)]
pub struct NativeModel {
    cfg: ModelConfig,
    /// quantize every quantized-linear input per token (the W4A4
    /// discipline the deployed artifacts use)
    act_quant: bool,
    tok_emb: Tensor,
    lm_head: Linear,
    attn_norm: Tensor,
    mlp_norm: Tensor,
    out_norm: Tensor,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    w_gate: Linear,
    w_up: Linear,
    w_down: Linear,
    /// RoPE tables, `[seq_len, head_dim/2]` row-major
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl NativeModel {
    /// Assemble a model from a quantized store: packed layers are carried
    /// packed, everything else dense. Every shape is validated against
    /// `cfg` so a mismatched store fails here, not mid-decode.
    pub fn new(cfg: &ModelConfig, store: &QuantParamStore, act_quant: bool) -> Result<NativeModel> {
        if cfg.head_dim * cfg.n_heads != cfg.d_model {
            bail!("head_dim * n_heads != d_model");
        }
        if cfg.head_dim % 2 != 0 {
            bail!("rope needs an even head_dim");
        }
        let (l, d, h, v) = (cfg.n_layers, cfg.d_model, cfg.mlp_hidden, cfg.vocab);
        let dense = |name: &str, shape: &[usize]| -> Result<Tensor> {
            let t = store.get(name)?;
            if t.shape != shape {
                bail!("weight '{name}': shape {:?} != expected {shape:?}", t.shape);
            }
            Ok(t)
        };
        let linear = |name: &str, shape: &[usize]| -> Result<Linear> {
            if let Some(q) = store.packed(name) {
                if q.shape != shape {
                    bail!("packed weight '{name}': shape {:?} != expected {shape:?}", q.shape);
                }
                Ok(Linear::from(q.clone()))
            } else {
                Ok(Linear::Dense(dense(name, shape)?))
            }
        };
        let (cos, sin) = ops::rope_tables(cfg.seq_len, cfg.head_dim);
        Ok(NativeModel {
            cfg: cfg.clone(),
            act_quant,
            tok_emb: dense("tok_emb", &[v, d])?,
            lm_head: Linear::Dense(dense("lm_head", &[d, v])?),
            attn_norm: dense("layers.attn_norm", &[l, d])?,
            mlp_norm: dense("layers.mlp_norm", &[l, d])?,
            out_norm: dense("out_norm", &[d])?,
            wq: linear("layers.wq", &[l, d, d])?,
            wk: linear("layers.wk", &[l, d, d])?,
            wv: linear("layers.wv", &[l, d, d])?,
            wo: linear("layers.wo", &[l, d, d])?,
            w_gate: linear("layers.w_gate", &[l, d, h])?,
            w_up: linear("layers.w_up", &[l, d, h])?,
            w_down: linear("layers.w_down", &[l, h, d])?,
            cos,
            sin,
        })
    }

    /// The model configuration.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// True when quantized-linear inputs are fake-quantized per token.
    pub fn act_quant(&self) -> bool {
        self.act_quant
    }

    /// Linear stacks held packed (0–7).
    pub fn n_packed(&self) -> usize {
        self.linears().iter().filter(|l| l.is_packed()).count()
    }

    /// Bytes of packed payload across the linear stacks.
    pub fn packed_payload_bytes(&self) -> usize {
        self.linears().iter().map(|l| l.payload_bytes()).sum()
    }

    fn linears(&self) -> [&Linear; 7] {
        [&self.wq, &self.wk, &self.wv, &self.wo, &self.w_gate, &self.w_up, &self.w_down]
    }

    /// The KV layout one cached token occupies for this model, in the
    /// given element storage format.
    pub fn kv_layout(&self, page_tokens: usize, format: KvFormat) -> KvLayout {
        KvLayout {
            n_layers: self.cfg.n_layers,
            d_model: self.cfg.d_model,
            page_tokens: page_tokens.max(1),
            format,
        }
    }

    /// Full-window forward: run every token of `tokens` through the
    /// decoder (with a scratch cache) and return the **last position's**
    /// logits — the reference the cached incremental path must match
    /// bit-for-bit. `tokens.len()` must be in `[1, seq_len]`.
    pub fn logits_window(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.logits_window_par(tokens, threads::default_workers())
    }

    /// [`Self::logits_window`] with an explicit column-parallelism
    /// budget for the fused kernels (1 when the caller is already inside
    /// a batch fan-out — thread pools must not nest). The scratch KV
    /// pool uses [`DEFAULT_PAGE_TOKENS`]-token `f32` pages; callers with
    /// a configured geometry use [`Self::logits_window_paged`].
    pub fn logits_window_par(&self, tokens: &[i32], col_workers: usize) -> Result<Vec<f32>> {
        self.logits_window_paged(tokens, DEFAULT_PAGE_TOKENS, KvFormat::F32, col_workers)
    }

    /// [`Self::logits_window_par`] with an explicit KV page size and
    /// element format for the scratch pool — the backend threads its
    /// `--kv-page-tokens` / `--kv-format` settings through here instead
    /// of a hardcoded geometry. Page size never changes the logits, only
    /// the allocation granularity; the format does (`e4m3` quantizes
    /// every cached row), which is why it is part of the signature and
    /// not a global.
    pub fn logits_window_paged(
        &self,
        tokens: &[i32],
        page_tokens: usize,
        kv_format: KvFormat,
        col_workers: usize,
    ) -> Result<Vec<f32>> {
        self.check_window(tokens)?;
        let layout = self.kv_layout(page_tokens, kv_format);
        let pool = Mutex::new(KvPool::unbounded(layout));
        let mut seq = KvSeq::new(layout);
        let mut s = Scratch::new(&self.cfg);
        let mut out = None;
        for (i, &tok) in tokens.iter().enumerate() {
            let last = i + 1 == tokens.len();
            out = self.feed(&mut seq, &pool, tok, i, last, &mut s, col_workers)?;
        }
        out.ok_or_else(|| anyhow!("empty decode window"))
    }

    /// Batched full-window forward — the **prefill path**: all window
    /// positions run the seven linear stacks in `[T, ·]` form through
    /// [`Linear::matmul`], so the packed payload is streamed and
    /// nibble-decoded once per [`kernels::TILE_M`]-row tile instead of
    /// once per token; attention / RoPE / norms stay per-position.
    /// Returns the last position's logits, **bit-identical** to
    /// [`Self::logits_window`] on the same tokens (pinned by tests).
    pub fn prefill(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.prefill_paged(tokens, DEFAULT_PAGE_TOKENS, KvFormat::F32, threads::default_workers())
    }

    /// [`Self::prefill`] with explicit scratch-pool page size, KV element
    /// format, and column-parallelism budget (1 inside a batch fan-out).
    pub fn prefill_paged(
        &self,
        tokens: &[i32],
        page_tokens: usize,
        kv_format: KvFormat,
        col_workers: usize,
    ) -> Result<Vec<f32>> {
        self.check_window(tokens)?;
        let layout = self.kv_layout(page_tokens, kv_format);
        let pool = Mutex::new(KvPool::unbounded(layout));
        let mut seq = KvSeq::new(layout);
        let mut s = RowScratch::new();
        self.prefill_into(&mut seq, &pool, tokens, 0, true, &mut s, col_workers)?
            .ok_or_else(|| anyhow!("empty decode window"))
    }

    fn check_window(&self, tokens: &[i32]) -> Result<()> {
        if tokens.is_empty() {
            bail!("empty decode window");
        }
        if tokens.len() > self.cfg.seq_len {
            bail!("window of {} tokens exceeds seq_len {}", tokens.len(), self.cfg.seq_len);
        }
        Ok(())
    }

    /// Run `tokens` through the decoder in batched `[T, ·]` form at
    /// window indices `start..start + T`, appending each position's
    /// keys/values to `seq` (pages reserved in one pool transaction via
    /// [`KvSeq::reserve`]). Returns the last position's logits when
    /// `want_logits`. `seq` must hold exactly `start` cached tokens.
    fn prefill_into(
        &self,
        seq: &mut KvSeq,
        pool: &Mutex<KvPool>,
        tokens: &[i32],
        start: usize,
        want_logits: bool,
        s: &mut RowScratch,
        col_workers: usize,
    ) -> Result<Option<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(None);
        }
        if start + tokens.len() > self.cfg.seq_len {
            bail!(
                "prefill of {} tokens at {start} exceeds seq_len {}",
                tokens.len(),
                self.cfg.seq_len
            );
        }
        if seq.len() != start {
            bail!("cache holds {} tokens, prefill expected {start}", seq.len());
        }
        {
            let mut pool = pool.lock().unwrap_or_else(|e| e.into_inner());
            seq.reserve(&mut pool, tokens.len())?;
        }
        let rows: Vec<(usize, i32, usize)> =
            tokens.iter().enumerate().map(|(i, &t)| (0, t, start + i)).collect();
        let first_logits = if want_logits { rows.len() - 1 } else { rows.len() };
        let mut seqs = [seq];
        let mut out = self.forward_rows(&mut seqs, &rows, first_logits, s, col_workers)?;
        Ok(out.pop())
    }

    /// Run one token through the decoder at window index `idx`, appending
    /// its keys/values to `seq`, and return the logits row when
    /// `want_logits` (the last window position). `col_workers` bounds the
    /// fused kernels' column parallelism (1 = scalar).
    fn feed(
        &self,
        seq: &mut KvSeq,
        pool: &Mutex<KvPool>,
        token: i32,
        idx: usize,
        want_logits: bool,
        s: &mut Scratch,
        col_workers: usize,
    ) -> Result<Option<Vec<f32>>> {
        let cfg = &self.cfg;
        let (d, hd, heads) = (cfg.d_model, cfg.head_dim, cfg.n_heads);
        if token < 0 || (token as usize) >= cfg.vocab {
            bail!("token id {token} outside [0, {})", cfg.vocab);
        }
        if idx >= cfg.seq_len {
            bail!("window index {idx} beyond seq_len {}", cfg.seq_len);
        }
        {
            let mut pool = pool.lock().unwrap_or_else(|e| e.into_inner());
            seq.push(&mut pool)?;
        }
        let t_new = seq.len() - 1;
        debug_assert_eq!(t_new, idx, "cache length out of sync with window index");

        let tok = token as usize;
        s.x.copy_from_slice(&self.tok_emb.data[tok * d..(tok + 1) * d]);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();

        for l in 0..cfg.n_layers {
            // ---- attention ------------------------------------------------
            ops::rmsnorm_into(&s.x, &self.attn_norm.data[l * d..(l + 1) * d], &mut s.a);
            if self.act_quant {
                ops::act_fake_quant(&mut s.a);
            }
            s.q.fill(0.0);
            self.wq.matvec(l, &s.a, &mut s.q, &mut s.scale_row, col_workers)?;
            s.k.fill(0.0);
            self.wk.matvec(l, &s.a, &mut s.k, &mut s.scale_row, col_workers)?;
            s.v.fill(0.0);
            self.wv.matvec(l, &s.a, &mut s.v, &mut s.scale_row, col_workers)?;
            ops::rope_inplace(&mut s.q, heads, hd, &self.cos, &self.sin, idx);
            ops::rope_inplace(&mut s.k, heads, hd, &self.cos, &self.sin, idx);
            seq.store_kv(t_new, l, &s.k, &s.v);
            let len = t_new + 1;
            s.attn.fill(0.0);
            // Two-pass attention, token-outer: each cached K/V row is
            // read through its decode view ONCE per layer (not once per
            // head) — for quantized storage that is one e4m3 decode per
            // row. Per (head, position) the float ops and, in pass 2,
            // the ascending-t accumulation order are exactly those of
            // the head-outer loop this replaced, so f32-cached logits
            // are unchanged bitwise.
            let sl = cfg.seq_len;
            for t in 0..len {
                let krow = seq.k_row(t, l, &mut s.kvbuf);
                for h_ in 0..heads {
                    let q_h = &s.q[h_ * hd..(h_ + 1) * hd];
                    s.scores[h_ * sl + t] =
                        ops::dot(q_h, &krow[h_ * hd..(h_ + 1) * hd]) * inv_sqrt;
                }
            }
            for h_ in 0..heads {
                ops::softmax_inplace(&mut s.scores[h_ * sl..h_ * sl + len]);
            }
            for t in 0..len {
                let vrow = seq.v_row(t, l, &mut s.kvbuf);
                for h_ in 0..heads {
                    let p = s.scores[h_ * sl + t];
                    let attn_h = &mut s.attn[h_ * hd..(h_ + 1) * hd];
                    let v_h = &vrow[h_ * hd..(h_ + 1) * hd];
                    for (o, &vv) in attn_h.iter_mut().zip(v_h) {
                        *o += p * vv;
                    }
                }
            }
            if self.act_quant {
                ops::act_fake_quant(&mut s.attn);
            }
            s.proj.fill(0.0);
            self.wo.matvec(l, &s.attn, &mut s.proj, &mut s.scale_row, col_workers)?;
            for (x, &p) in s.x.iter_mut().zip(&s.proj) {
                *x += p;
            }

            // ---- SwiGLU mlp -----------------------------------------------
            ops::rmsnorm_into(&s.x, &self.mlp_norm.data[l * d..(l + 1) * d], &mut s.a);
            if self.act_quant {
                ops::act_fake_quant(&mut s.a);
            }
            s.g.fill(0.0);
            self.w_gate.matvec(l, &s.a, &mut s.g, &mut s.scale_row, col_workers)?;
            s.u.fill(0.0);
            self.w_up.matvec(l, &s.a, &mut s.u, &mut s.scale_row, col_workers)?;
            for (g, &u) in s.g.iter_mut().zip(&s.u) {
                *g = ops::silu(*g) * u;
            }
            if self.act_quant {
                ops::act_fake_quant(&mut s.g);
            }
            s.proj.fill(0.0);
            self.w_down.matvec(l, &s.g, &mut s.proj, &mut s.scale_row, col_workers)?;
            for (x, &p) in s.x.iter_mut().zip(&s.proj) {
                *x += p;
            }
        }

        if !want_logits {
            return Ok(None);
        }
        ops::rmsnorm_into(&s.x, &self.out_norm.data, &mut s.a);
        let mut logits = vec![0.0f32; cfg.vocab];
        self.lm_head.matvec(0, &s.a, &mut logits, &mut s.scale_row, col_workers)?;
        Ok(Some(logits))
    }

    /// The multi-row forward core shared by the prefill path (rows =
    /// consecutive positions of ONE sequence) and the cross-slot batched
    /// decode (rows = one position from EACH active slot). Every linear
    /// runs once per layer over all rows through [`Linear::matmul`];
    /// RoPE, norms, activation fake-quant, and attention stay
    /// per-position, reading only the row's own sequence. Row `i`'s
    /// result is therefore bitwise identical to feeding row `i` through
    /// [`Self::feed`] — the invariant every batched==sequential and
    /// prefill==token-by-token parity test leans on.
    ///
    /// `rows` entries are `(seq index, token, window index)`; each row's
    /// KV slot must already be reserved in its sequence. Rows sharing a
    /// sequence must be in ascending window order (the prefill case) so
    /// attention at row `i` only reads positions `<= i`, all written
    /// before any attention runs. Logits come back for rows
    /// `first_logits_row..`, in row order.
    fn forward_rows(
        &self,
        seqs: &mut [&mut KvSeq],
        rows: &[(usize, i32, usize)],
        first_logits_row: usize,
        s: &mut RowScratch,
        col_workers: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let cfg = &self.cfg;
        let (d, hd, heads, h) = (cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.mlp_hidden);
        let b = rows.len();
        if b == 0 {
            return Ok(vec![]);
        }
        for &(si, token, idx) in rows {
            if si >= seqs.len() {
                bail!("row references sequence {si} of {}", seqs.len());
            }
            if token < 0 || (token as usize) >= cfg.vocab {
                bail!("token id {token} outside [0, {})", cfg.vocab);
            }
            if idx >= cfg.seq_len {
                bail!("window index {idx} beyond seq_len {}", cfg.seq_len);
            }
            if seqs[si].len() <= idx {
                bail!("kv slot {idx} not reserved (cache holds {})", seqs[si].len());
            }
        }
        s.ensure(cfg, b);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        for (ri, &(_, token, _)) in rows.iter().enumerate() {
            let tok = token as usize;
            s.x[ri * d..(ri + 1) * d].copy_from_slice(&self.tok_emb.data[tok * d..(tok + 1) * d]);
        }

        for l in 0..cfg.n_layers {
            // ---- attention ------------------------------------------------
            for ri in 0..b {
                ops::rmsnorm_into(
                    &s.x[ri * d..(ri + 1) * d],
                    &self.attn_norm.data[l * d..(l + 1) * d],
                    &mut s.a[ri * d..(ri + 1) * d],
                );
                if self.act_quant {
                    ops::act_fake_quant(&mut s.a[ri * d..(ri + 1) * d]);
                }
            }
            s.q.fill(0.0);
            self.wq.matmul(l, &s.a, b, &mut s.q, &mut s.scale_row, col_workers)?;
            s.k.fill(0.0);
            self.wk.matmul(l, &s.a, b, &mut s.k, &mut s.scale_row, col_workers)?;
            s.v.fill(0.0);
            self.wv.matmul(l, &s.a, b, &mut s.v, &mut s.scale_row, col_workers)?;
            // RoPE + cache writes for every row, THEN attention: rows
            // sharing a sequence (prefill) see all their predecessors
            for (ri, &(si, _, idx)) in rows.iter().enumerate() {
                ops::rope_inplace(
                    &mut s.q[ri * d..(ri + 1) * d],
                    heads,
                    hd,
                    &self.cos,
                    &self.sin,
                    idx,
                );
                ops::rope_inplace(
                    &mut s.k[ri * d..(ri + 1) * d],
                    heads,
                    hd,
                    &self.cos,
                    &self.sin,
                    idx,
                );
                seqs[si].store_kv(idx, l, &s.k[ri * d..(ri + 1) * d], &s.v[ri * d..(ri + 1) * d]);
            }
            s.attn.fill(0.0);
            // per-row attention is embarrassingly parallel once every
            // KV write above has landed: row `ri` reads only its own
            // sequence prefix and writes only its own attn/scores/kvbuf
            // chunk, each computed wholly by one worker — so the result
            // is identical for any worker count. Within a job the sweep
            // is token-outer two-pass (same op order per head as the
            // head-outer loop it replaced, see `feed`), so each cached
            // row is decoded once per layer.
            {
                let seqs_ro: &[&mut KvSeq] = seqs;
                let q_ro: &[f32] = &s.q;
                let act_quant = self.act_quant;
                let sl = cfg.seq_len;
                let jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [f32])> = s
                    .attn
                    .chunks_mut(d)
                    .zip(s.scores.chunks_mut(heads * sl))
                    .zip(s.kvbuf.chunks_mut(d))
                    .enumerate()
                    .map(|(ri, ((attn_row, scores_row), kv_row))| {
                        (ri, attn_row, scores_row, kv_row)
                    })
                    .collect();
                threads::par_map(jobs, col_workers, |(ri, attn_row, scores_row, kv_row)| {
                    let (si, _, idx) = rows[ri];
                    let len = idx + 1;
                    let seq = &seqs_ro[si];
                    for t in 0..len {
                        let krow = seq.k_row(t, l, &mut kv_row[..]);
                        for h_ in 0..heads {
                            let q_h = &q_ro[ri * d + h_ * hd..ri * d + (h_ + 1) * hd];
                            scores_row[h_ * sl + t] =
                                ops::dot(q_h, &krow[h_ * hd..(h_ + 1) * hd]) * inv_sqrt;
                        }
                    }
                    for h_ in 0..heads {
                        ops::softmax_inplace(&mut scores_row[h_ * sl..h_ * sl + len]);
                    }
                    for t in 0..len {
                        let vrow = seq.v_row(t, l, &mut kv_row[..]);
                        for h_ in 0..heads {
                            let p = scores_row[h_ * sl + t];
                            let attn_h = &mut attn_row[h_ * hd..(h_ + 1) * hd];
                            let v_h = &vrow[h_ * hd..(h_ + 1) * hd];
                            for (o, &vv) in attn_h.iter_mut().zip(v_h) {
                                *o += p * vv;
                            }
                        }
                    }
                    if act_quant {
                        ops::act_fake_quant(attn_row);
                    }
                });
            }
            s.proj.fill(0.0);
            self.wo.matmul(l, &s.attn, b, &mut s.proj, &mut s.scale_row, col_workers)?;
            for (x, &p) in s.x.iter_mut().zip(&s.proj) {
                *x += p;
            }

            // ---- SwiGLU mlp -----------------------------------------------
            for ri in 0..b {
                ops::rmsnorm_into(
                    &s.x[ri * d..(ri + 1) * d],
                    &self.mlp_norm.data[l * d..(l + 1) * d],
                    &mut s.a[ri * d..(ri + 1) * d],
                );
                if self.act_quant {
                    ops::act_fake_quant(&mut s.a[ri * d..(ri + 1) * d]);
                }
            }
            s.g.fill(0.0);
            self.w_gate.matmul(l, &s.a, b, &mut s.g, &mut s.scale_row, col_workers)?;
            s.u.fill(0.0);
            self.w_up.matmul(l, &s.a, b, &mut s.u, &mut s.scale_row, col_workers)?;
            for (g, &u) in s.g.iter_mut().zip(&s.u) {
                *g = ops::silu(*g) * u;
            }
            if self.act_quant {
                for ri in 0..b {
                    ops::act_fake_quant(&mut s.g[ri * h..(ri + 1) * h]);
                }
            }
            s.proj.fill(0.0);
            self.w_down.matmul(l, &s.g, b, &mut s.proj, &mut s.scale_row, col_workers)?;
            for (x, &p) in s.x.iter_mut().zip(&s.proj) {
                *x += p;
            }
        }

        if first_logits_row >= b {
            return Ok(vec![]);
        }
        let nl = b - first_logits_row;
        for ri in first_logits_row..b {
            ops::rmsnorm_into(
                &s.x[ri * d..(ri + 1) * d],
                &self.out_norm.data,
                &mut s.a[ri * d..(ri + 1) * d],
            );
        }
        s.logits.clear();
        s.logits.resize(nl * cfg.vocab, 0.0);
        self.lm_head.matmul(
            0,
            &s.a[first_logits_row * d..],
            nl,
            &mut s.logits,
            &mut s.scale_row,
            col_workers,
        )?;
        Ok(s.logits.chunks(cfg.vocab).map(|c| c.to_vec()).collect())
    }
}

/// Serving knobs for the native backend.
#[derive(Clone, Copy, Debug)]
pub struct NativeOptions {
    /// reuse cached keys/values across steps (the O(T) decode path);
    /// false recomputes the full window every step (the reference path)
    pub use_cache: bool,
    /// cached tokens per KV page
    pub page_tokens: usize,
    /// KV pool cap, in pages, across all in-flight slots
    pub max_pages: usize,
    /// element storage format for cached K/V rows (`--kv-format`):
    /// [`KvFormat::F32`] keeps serving bit-exact against the uncached
    /// reference; [`KvFormat::E4m3`] packs rows to FP8 for 4x the cached
    /// tokens per byte budget, within a tested logits tolerance
    pub kv_format: KvFormat,
    /// worker threads for the phase-1 per-slot fan-out and the fused
    /// kernels' column-parallel budget (0 = auto)
    pub workers: usize,
    /// share full prompt pages across requests through the
    /// [`prefix::PrefixCache`] trie (`--prefix-cache`): a request whose
    /// prompt shares a full-page prefix with an earlier one attaches to
    /// the cached pages and prefills only its suffix, with bit-identical
    /// logits. Off by default — the trie retains pages between requests,
    /// so `kv_outstanding` stays above zero until the trie is cleared
    pub prefix_cache: bool,
}

impl Default for NativeOptions {
    fn default() -> NativeOptions {
        NativeOptions {
            use_cache: true,
            page_tokens: DEFAULT_PAGE_TOKENS,
            max_pages: 4096,
            kv_format: KvFormat::F32,
            workers: 0,
            prefix_cache: false,
        }
    }
}

/// Per-slot cache entry: the KV pages, the window tokens they represent
/// (the resync key the `StepBackend` impl on [`NativeBackend`]
/// re-derives every step), and the slot's reusable prefill buffers — so
/// catch-up (admission, window slide) reuses one allocation.
struct SlotCache {
    kv: KvSeq,
    history: Vec<i32>,
    scratch: RowScratch,
}

/// What phase 1 of a batched step left one slot owing.
enum Phase1 {
    /// slot already finished; its row is discarded by `decode_step`
    Done,
    /// full logits row (or error) computed slot-locally — uncached mode
    /// and the pool-exhaustion fallback
    Row(Result<Vec<f32>>),
    /// caught up: exactly the decode token remains, validated, with its
    /// KV slot reserved — joins the cross-slot batch in phase 2
    Pending {
        /// the decode token (last window token)
        token: i32,
        /// its window index
        idx: usize,
    },
}

/// [`StepBackend`] over a [`NativeModel`]: batched logits-out decode in
/// pure rust, with per-slot KV caches shared out of one bounded page
/// pool (token selection — greedy or sampled — happens in the decode
/// core, never here).
///
/// A batched step runs in two phases. **Phase 1** (fanned out per slot)
/// brings every slot's cache up to "all but the decode token fed" — a
/// fresh slot's prompt goes through the batched prefill path in one
/// `[T, ·]` pass instead of T matvec sweeps. **Phase 2** gathers the
/// active slots' decode tokens into one `[B, ·]` cross-slot pass
/// through [`Linear::matmul`], so each packed layer is streamed and
/// nibble-decoded once per step instead of once per slot. Row `i` still
/// depends only on slot `i` (the per-slot KV/attention state never
/// crosses rows, and every matmul row is bitwise identical to the
/// matvec of that row), so batched output stays token-identical to
/// sequential output — the same invariant the synthetic and XLA
/// backends keep, now preserved *through* the shared kernels.
///
/// Cache coherence is re-derived every step from the slot's visible
/// window: if the cached token history is a strict prefix of the window,
/// only the missing suffix is fed (O(1) per decode step); anything else
/// — a fresh slot, or a window that slid past `seq_len` — rebuilds the
/// slot's cache from scratch (also via prefill). On pool exhaustion a
/// slot falls back to uncached full-window compute instead of failing
/// the request. Every path produces bit-identical logits.
pub struct NativeBackend {
    model: NativeModel,
    opts: NativeOptions,
    layout: KvLayout,
    /// page pool + per-slot cache registry. These locks (and the trie
    /// and scratch below) recover from poisoning (`into_inner`) instead
    /// of cascading a panic: each critical section either performs one
    /// structural map/pool operation or fills buffers that the next
    /// holder overwrites from scratch, so the state a panicking thread
    /// leaves behind is still coherent — and `release` MUST keep working
    /// after a contained `step` panic or slot pages would leak forever
    pool: Mutex<KvPool>,
    seqs: Mutex<HashMap<u64, SlotCache>>,
    /// the shared-prefix page trie, present when
    /// [`NativeOptions::prefix_cache`] is on. Lock order: trie first,
    /// then pool — eviction holds both
    prefix: Option<Mutex<PrefixCache>>,
    /// reusable buffers for the phase-2 cross-slot pass, so steady-state
    /// batched decode allocates nothing per step
    batch_scratch: Mutex<RowScratch>,
}

impl NativeBackend {
    /// Wrap a model with a KV pool sized by `opts`.
    pub fn new(model: NativeModel, opts: NativeOptions) -> NativeBackend {
        let layout = model.kv_layout(opts.page_tokens, opts.kv_format);
        let pool = Mutex::new(KvPool::new(layout, opts.max_pages));
        let prefix = (opts.prefix_cache && opts.use_cache)
            .then(|| Mutex::new(PrefixCache::new(layout.page_tokens)));
        NativeBackend {
            model,
            opts,
            layout,
            pool,
            seqs: Mutex::new(HashMap::new()),
            prefix,
            batch_scratch: Mutex::new(RowScratch::new()),
        }
    }

    /// The wrapped model.
    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    /// KV pages currently held by live slots (0 once every request has
    /// been released — the leak regression tests assert on this).
    pub fn kv_outstanding(&self) -> usize {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).outstanding()
    }

    /// Slots with a live cache entry.
    pub fn cached_slots(&self) -> usize {
        self.seqs.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// Peak KV pages outstanding over the backend's lifetime — the
    /// pages-in-use high-water mark surfaced in the serve stats.
    pub fn kv_high_water(&self) -> usize {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).high_water()
    }

    /// Prefix-cache counters, `None` unless
    /// [`NativeOptions::prefix_cache`] is on.
    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|t| t.lock().unwrap_or_else(|e| e.into_inner()).stats())
    }

    /// Release every page the prefix trie holds back into the pool.
    /// With no slots in flight this brings [`Self::kv_outstanding`] back
    /// to zero — what the leak/drain tests assert after exercising
    /// sharing.
    pub fn clear_prefix_cache(&self) {
        if let Some(trie) = &self.prefix {
            // lock order: trie, then pool
            let mut trie = trie.lock().unwrap_or_else(|e| e.into_inner());
            let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
            trie.clear(&mut pool);
        }
    }

    fn workers_for(&self, batch: usize) -> usize {
        let w = if self.opts.workers > 0 { self.opts.workers } else { threads::default_workers() };
        w.min(batch).max(1)
    }

    /// Column-parallelism budget when nothing else is fanned out (single
    /// slot, or the phase-2 cross-slot pass on the coordinating thread).
    fn col_workers_full(&self) -> usize {
        if self.opts.workers > 0 {
            self.opts.workers
        } else {
            threads::default_workers()
        }
    }

    /// Full-window logits on a scratch pool through the batched prefill
    /// path — bit-identical to the cached path in the *same* KV format,
    /// used for uncached mode and the pool-exhaustion fallback. Respects
    /// the configured KV page size and element format (an `e4m3` backend
    /// must fall back to an `e4m3` recompute, or the fallback would
    /// change the logits).
    fn full_window(&self, want: &[i32], col_workers: usize) -> Result<Vec<f32>> {
        self.model.prefill_paged(want, self.opts.page_tokens, self.opts.kv_format, col_workers)
    }

    /// Phase 1 for one slot: catch the cache up to "all but the decode
    /// token fed" (batched prefill), reserve the decode token's KV slot,
    /// and hand back what the slot still owes. The entry always comes
    /// back so its pages are never lost, even on error. `col_workers` is
    /// 1 whenever this runs under the per-slot fan-out.
    fn phase1_slot(
        &self,
        slot: &DecodeSlot,
        entry: Option<SlotCache>,
        col_workers: usize,
    ) -> (Phase1, Option<SlotCache>) {
        let want = slot.window();
        if !self.opts.use_cache {
            return (Phase1::Row(self.full_window(want, col_workers)), None);
        }
        let mut entry = entry.unwrap_or_else(|| SlotCache {
            kv: KvSeq::new(self.layout),
            history: Vec::new(),
            scratch: RowScratch::new(),
        });
        // on exhaustion, reclaim cold prefix-cache pages (LRU) and retry
        // the cached path before giving up on it; evict_prefix_lru
        // returning false (trie empty) bounds the loop
        let res = loop {
            match self.catch_up(want, &mut entry, col_workers) {
                Err(e)
                    if e.downcast_ref::<kv::KvExhausted>().is_some()
                        && self.evict_prefix_lru() =>
                {
                    continue;
                }
                other => break other,
            }
        };
        match res {
            Ok((token, idx)) => (Phase1::Pending { token, idx }, Some(entry)),
            Err(e) if e.downcast_ref::<kv::KvExhausted>().is_some() => {
                // free this slot's pages for its neighbours and fall back
                // to uncached compute — same logits, O(window) extra cost
                self.clear_entry(&mut entry);
                crate::debug!(
                    "kv pool exhausted; slot {} falling back to uncached decode",
                    slot.id
                );
                (Phase1::Row(self.full_window(want, col_workers)), Some(entry))
            }
            Err(e) => {
                self.clear_entry(&mut entry);
                (Phase1::Row(Err(e)), Some(entry))
            }
        }
    }

    /// Re-derive cache coherence from the slot's visible window, feed
    /// everything but the last window token in one batched prefill pass,
    /// and reserve the decode token's KV slot so phase 2 cannot fail on
    /// pool exhaustion mid-batch. Returns the validated decode token and
    /// its window index.
    fn catch_up(
        &self,
        want: &[i32],
        entry: &mut SlotCache,
        col_workers: usize,
    ) -> Result<(i32, usize)> {
        let cached = entry.history.len();
        let prefix_ok = cached < want.len()
            && cached == entry.kv.len()
            && want[..cached] == entry.history[..];
        if !prefix_ok {
            self.clear_entry(entry);
        }
        // a cold slot first attaches the longest cached full-page prefix
        // from the trie, so only the suffix prefills below
        if entry.history.is_empty() {
            self.attach_prefix(want, entry);
        }
        let start = entry.history.len();
        let last = want.len() - 1;
        // validate the decode token slot-locally, before it joins the
        // shared phase-2 batch
        let token = want[last];
        if token < 0 || (token as usize) >= self.model.cfg.vocab {
            bail!("token id {token} outside [0, {})", self.model.cfg.vocab);
        }
        if start < last {
            self.model.prefill_into(
                &mut entry.kv,
                &self.pool,
                &want[start..last],
                start,
                false,
                &mut entry.scratch,
                col_workers,
            )?;
            entry.history.extend_from_slice(&want[start..last]);
            self.publish_prefix(want, last, entry);
        }
        {
            let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
            entry.kv.reserve(&mut pool, 1)?;
        }
        Ok((token, last))
    }

    /// Attach the longest trie-cached full-page prefix of the window's
    /// to-cache tokens (`want[..last]`) to a **cold** slot. A trie page
    /// holds exactly the bytes a cold prefill of the same tokens at the
    /// same (position-0-based) indices would store — in this backend's
    /// KV format — so attaching cannot change any later logits.
    fn attach_prefix(&self, want: &[i32], entry: &mut SlotCache) {
        let Some(trie) = &self.prefix else { return };
        debug_assert!(entry.kv.is_empty() && entry.history.is_empty());
        let last = want.len() - 1;
        if last == 0 {
            return;
        }
        let pages = trie.lock().unwrap_or_else(|e| e.into_inner()).lookup(&want[..last]);
        let pt = self.layout.page_tokens;
        for (i, page) in pages.into_iter().enumerate() {
            entry.kv.attach(page);
            entry.history.extend_from_slice(&want[i * pt..(i + 1) * pt]);
        }
    }

    /// After a successful prefill, publish the window's **full** prompt
    /// pages (`want[..last]`, which the slot has just cached) into the
    /// trie so later requests sharing the prefix attach instead of
    /// recomputing. The partial tail page stays exclusive to the slot —
    /// only-full-pages-shared is what keeps every KV write refcount-1.
    /// First writer wins inside the trie, so re-publishing a cached
    /// prefix is a cheap no-op.
    fn publish_prefix(&self, want: &[i32], last: usize, entry: &SlotCache) {
        let Some(trie) = &self.prefix else { return };
        let pt = self.layout.page_tokens;
        let full = last / pt;
        if full == 0 {
            return;
        }
        let pages: Vec<_> = (0..full).map(|i| entry.kv.page_handle(i)).collect();
        trie.lock().unwrap_or_else(|e| e.into_inner()).publish(&want[..full * pt], &pages);
    }

    /// Reclaim the least-recently-used prefix-cache page for the pool.
    /// Returns false when there is no trie or nothing left to evict —
    /// the termination condition of the exhaustion-retry loops.
    fn evict_prefix_lru(&self) -> bool {
        let Some(trie) = &self.prefix else { return false };
        // lock order: trie, then pool
        let mut trie = trie.lock().unwrap_or_else(|e| e.into_inner());
        let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        trie.evict_lru(&mut pool)
    }

    fn clear_entry(&self, entry: &mut SlotCache) {
        entry.kv.clear(&mut self.pool.lock().unwrap_or_else(|e| e.into_inner()));
        entry.history.clear();
    }

    /// The incremental-prefill core behind `StepBackend::prefill_chunk`:
    /// bring the slot's cache up to at most `start + max_tokens` of the
    /// window's to-cache tokens, attaching a trie prefix first on a cold
    /// slot. Returns the count still missing (0 = ready for decode).
    fn prefill_chunk_entry(
        &self,
        want: &[i32],
        max_tokens: usize,
        entry: &mut SlotCache,
    ) -> Result<usize> {
        let cached = entry.history.len();
        let prefix_ok = cached < want.len()
            && cached == entry.kv.len()
            && want[..cached] == entry.history[..];
        if !prefix_ok {
            self.clear_entry(entry);
        }
        if entry.history.is_empty() {
            self.attach_prefix(want, entry);
        }
        let last = want.len() - 1;
        let start = entry.history.len();
        if start >= last {
            return Ok(0);
        }
        let stop = last.min(start + max_tokens);
        let res = loop {
            match self.model.prefill_into(
                &mut entry.kv,
                &self.pool,
                &want[start..stop],
                start,
                false,
                &mut entry.scratch,
                self.col_workers_full(),
            ) {
                Err(e)
                    if e.downcast_ref::<kv::KvExhausted>().is_some()
                        && self.evict_prefix_lru() =>
                {
                    continue;
                }
                other => break other,
            }
        };
        match res {
            Ok(_) => {
                entry.history.extend_from_slice(&want[start..stop]);
                if stop == last {
                    self.publish_prefix(want, last, entry);
                }
                Ok(last - stop)
            }
            Err(e) if e.downcast_ref::<kv::KvExhausted>().is_some() => {
                // no page budget for incremental prefill: report "done"
                // and let the step-time path use its uncached fallback
                Ok(0)
            }
            Err(e) => Err(e),
        }
    }

    /// Take a slot's cache entry out of the registry — creating a cold
    /// one if absent — so a spec-decode operation runs without holding
    /// the map lock. Every taker must reinsert via [`Self::put_entry`]
    /// on ALL exit paths or the slot's pages leak.
    fn take_entry(&self, slot_id: u64) -> SlotCache {
        let mut seqs = self.seqs.lock().unwrap_or_else(|e| e.into_inner());
        seqs.remove(&slot_id).unwrap_or_else(|| SlotCache {
            kv: KvSeq::new(self.layout),
            history: Vec::new(),
            scratch: RowScratch::new(),
        })
    }

    fn put_entry(&self, slot_id: u64, entry: SlotCache) {
        self.seqs.lock().unwrap_or_else(|e| e.into_inner()).insert(slot_id, entry);
    }

    /// One cached logits row for an arbitrary decode `window`, keyed on
    /// `slot_id` — the single-sequence sibling of the batched
    /// `StepBackend::step`, with the same coherence rules (cached prefix
    /// reused, suffix prefilled, anything else rebuilt) and the same
    /// uncached full-window fallback on pool exhaustion, so it never
    /// fails a request for page pressure. The speculative decoder steps
    /// the *draft* model through this, and uses it as the plain-step
    /// fallback when drafting is not worthwhile. Bitwise identical to
    /// what `step` would return for a slot with this window.
    pub fn decode_row(&self, slot_id: u64, window: &[i32]) -> Result<Vec<f32>> {
        if window.is_empty() {
            bail!("decode_row on an empty window");
        }
        let cw = self.col_workers_full();
        if !self.opts.use_cache {
            return self.full_window(window, cw);
        }
        let mut entry = self.take_entry(slot_id);
        let res = loop {
            match self.catch_up(window, &mut entry, cw) {
                Err(e)
                    if e.downcast_ref::<kv::KvExhausted>().is_some()
                        && self.evict_prefix_lru() =>
                {
                    continue;
                }
                other => break other,
            }
        };
        let out = match res {
            Ok((token, idx)) => {
                let SlotCache { kv, history, scratch } = &mut entry;
                match self.model.forward_rows(&mut [kv], &[(0, token, idx)], 0, scratch, cw) {
                    Ok(mut rows) => {
                        history.push(token);
                        Ok(rows.pop().expect("single-row forward returned no row"))
                    }
                    Err(e) => {
                        self.clear_entry(&mut entry);
                        Err(e)
                    }
                }
            }
            Err(e) if e.downcast_ref::<kv::KvExhausted>().is_some() => {
                self.clear_entry(&mut entry);
                self.full_window(window, cw)
            }
            Err(e) => {
                self.clear_entry(&mut entry);
                Err(e)
            }
        };
        self.put_entry(slot_id, entry);
        out
    }

    /// The draft-verify pass: logits rows for `window`'s decode token
    /// *and* each of `drafts` appended after it, computed in ONE batched
    /// [`NativeModel::forward_rows`] call over `drafts.len() + 1`
    /// consecutive positions of the slot's cached sequence. Row `i` is
    /// bitwise identical to what sequential [`Self::decode_row`] calls
    /// feeding `drafts[..i]` would return — the property that lets the
    /// speculative decoder accept a matching prefix without changing the
    /// output stream. On success the slot's cache holds
    /// `window + drafts`; the caller rolls back rejected suffixes with
    /// [`Self::truncate_slot`]. Pool exhaustion surfaces as a typed
    /// `KvExhausted` error with the cache intact (rolled back to the
    /// window prefix), so callers can fall back to a plain step.
    pub fn verify_rows(
        &self,
        slot_id: u64,
        window: &[i32],
        drafts: &[i32],
    ) -> Result<Vec<Vec<f32>>> {
        if drafts.is_empty() {
            return self.decode_row(slot_id, window).map(|r| vec![r]);
        }
        if window.is_empty() {
            bail!("verify_rows on an empty window");
        }
        if window.len() + drafts.len() > self.model.cfg.seq_len {
            bail!(
                "verify window of {} + {} drafts overflows seq_len {}",
                window.len(),
                drafts.len(),
                self.model.cfg.seq_len
            );
        }
        for &t in drafts {
            if t < 0 || (t as usize) >= self.model.cfg.vocab {
                bail!("draft token id {t} outside [0, {})", self.model.cfg.vocab);
            }
        }
        let cw = self.col_workers_full();
        if !self.opts.use_cache {
            // uncached reference path: one full-window recompute per row.
            // Slow, but keeps the API total — the CLI gates spec decode
            // on the cached backend.
            let mut rows = Vec::with_capacity(drafts.len() + 1);
            let mut w = window.to_vec();
            rows.push(self.full_window(&w, cw)?);
            for &d in drafts {
                w.push(d);
                rows.push(self.full_window(&w, cw)?);
            }
            return Ok(rows);
        }
        let mut entry = self.take_entry(slot_id);
        let res = loop {
            match self.catch_up(window, &mut entry, cw) {
                Err(e)
                    if e.downcast_ref::<kv::KvExhausted>().is_some()
                        && self.evict_prefix_lru() =>
                {
                    continue;
                }
                other => break other,
            }
        };
        let out = match res {
            Ok((token, idx)) => {
                let reserved = loop {
                    let r = {
                        let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
                        entry.kv.reserve(&mut pool, drafts.len())
                    };
                    match r {
                        Err(e)
                            if e.downcast_ref::<kv::KvExhausted>().is_some()
                                && self.evict_prefix_lru() =>
                        {
                            continue;
                        }
                        other => break other,
                    }
                };
                match reserved {
                    Ok(()) => {
                        let mut rows_spec = Vec::with_capacity(drafts.len() + 1);
                        rows_spec.push((0usize, token, idx));
                        for (i, &d) in drafts.iter().enumerate() {
                            rows_spec.push((0, d, idx + 1 + i));
                        }
                        let SlotCache { kv, history, scratch } = &mut entry;
                        match self.model.forward_rows(&mut [kv], &rows_spec, 0, scratch, cw) {
                            Ok(rows) => {
                                history.push(token);
                                history.extend_from_slice(drafts);
                                Ok(rows)
                            }
                            Err(e) => {
                                self.clear_entry(&mut entry);
                                Err(e)
                            }
                        }
                    }
                    Err(e) => {
                        // roll the dangling decode-token reservation back so
                        // the cached window prefix survives for the fallback
                        let keep = entry.history.len();
                        let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
                        let new_len = entry.kv.truncate(&mut pool, keep);
                        drop(pool);
                        entry.history.truncate(new_len);
                        Err(e)
                    }
                }
            }
            Err(e) => {
                // exhaustion included: verify has no uncached fallback of
                // its own — the caller degrades to decode_row, which does
                self.clear_entry(&mut entry);
                Err(e)
            }
        };
        self.put_entry(slot_id, entry);
        out
    }

    /// Roll a slot's cache back to its first `keep` tokens — the
    /// rejected-draft cleanup after a [`Self::verify_rows`] pass whose
    /// proposals were not all accepted. Unknown slots are a no-op. The
    /// cache may end up *shorter* than `keep` (a shared prefix page
    /// cannot be truncated mid-page); the next catch-up re-prefills the
    /// difference, so logits are unaffected either way.
    pub fn truncate_slot(&self, slot_id: u64, keep: usize) {
        let entry = self.seqs.lock().unwrap_or_else(|e| e.into_inner()).remove(&slot_id);
        if let Some(mut e) = entry {
            let new_len = {
                let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
                e.kv.truncate(&mut pool, keep)
            };
            e.history.truncate(new_len);
            self.put_entry(slot_id, e);
        }
    }
}

impl StepBackend for NativeBackend {
    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn step(&self, slots: &[DecodeSlot]) -> Result<Vec<Vec<f32>>> {
        if slots.is_empty() {
            return Ok(vec![]);
        }
        // take each slot's cache entry out of the shared map so the batch
        // runs without holding any lock on the hot path (entries own
        // their pages outright)
        let entries: Vec<Option<SlotCache>> = if self.opts.use_cache {
            let mut seqs = self.seqs.lock().unwrap_or_else(|e| e.into_inner());
            slots.iter().map(|s| seqs.remove(&s.id)).collect()
        } else {
            slots.iter().map(|_| None).collect()
        };
        // Phase 1 — per-slot catch-up, fanned out across slots. Worker
        // pools never nest: with several slots in flight each slot's
        // prefill runs scalar; a lone slot gets the full column budget.
        let col_workers = if slots.len() == 1 { self.col_workers_full() } else { 1 };
        let jobs: Vec<(usize, Option<SlotCache>)> = entries.into_iter().enumerate().collect();
        let phase1 = threads::par_map(jobs, self.workers_for(slots.len()), |(i, entry)| {
            let slot = &slots[i];
            if slot.done() {
                // decode_step discards finished slots' rows without
                // reading them — skip the forward (and the cache churn a
                // non-growing window would cause) instead of recomputing
                return (Phase1::Done, entry);
            }
            self.phase1_slot(slot, entry, col_workers)
        });
        let mut outcomes = Vec::with_capacity(slots.len());
        let mut entries: Vec<Option<SlotCache>> = Vec::with_capacity(slots.len());
        for (o, e) in phase1 {
            outcomes.push(o);
            entries.push(e);
        }
        // Phase 2 — ONE pass over each packed layer for every pending
        // slot: their decode tokens run the linear stacks as a [B, ·]
        // matmul on the coordinating thread (full column budget; the
        // per-slot fan-out has already joined).
        let mut pend_idx: Vec<usize> = Vec::new();
        let batch_res = {
            let mut seq_refs: Vec<&mut KvSeq> = Vec::new();
            let mut brows: Vec<(usize, i32, usize)> = Vec::new();
            for (i, (outcome, entry)) in outcomes.iter().zip(entries.iter_mut()).enumerate() {
                if let Phase1::Pending { token, idx } = *outcome {
                    brows.push((seq_refs.len(), token, idx));
                    seq_refs
                        .push(&mut entry.as_mut().expect("pending slot without cache entry").kv);
                    pend_idx.push(i);
                }
            }
            if brows.is_empty() {
                Ok(vec![])
            } else {
                let mut s = self.batch_scratch.lock().unwrap_or_else(|e| e.into_inner());
                self.model.forward_rows(&mut seq_refs, &brows, 0, &mut s, self.col_workers_full())
            }
        };
        // merge phase-2 rows back into per-slot results
        let mut results: Vec<Result<Vec<f32>>> = Vec::with_capacity(slots.len());
        match batch_res {
            Ok(batch_rows) => {
                let mut br = batch_rows.into_iter();
                for (i, outcome) in outcomes.into_iter().enumerate() {
                    results.push(match outcome {
                        Phase1::Done => Ok(Vec::new()),
                        Phase1::Row(r) => r,
                        Phase1::Pending { token, .. } => {
                            let row = br.next().expect("phase-2 row count mismatch");
                            entries[i].as_mut().expect("pending entry").history.push(token);
                            Ok(row)
                        }
                    });
                }
            }
            Err(e) => {
                // a batch-level failure cannot be attributed to one slot:
                // clear every pending entry (their reserved KV slots are
                // in an unknown state) and surface the error once
                let mut first = Some(e);
                for (i, outcome) in outcomes.into_iter().enumerate() {
                    results.push(match outcome {
                        Phase1::Done => Ok(Vec::new()),
                        Phase1::Row(r) => r,
                        Phase1::Pending { .. } => {
                            if let Some(entry) = entries[i].as_mut() {
                                self.clear_entry(entry);
                            }
                            match first.take() {
                                Some(e) => Err(e),
                                None => Err(anyhow!("cross-slot batched step failed")),
                            }
                        }
                    });
                }
            }
        }
        // reinsert every returned entry before surfacing any error, so a
        // failed step never strands pages outside the registry
        let mut rows = Vec::with_capacity(slots.len());
        let mut first_err = None;
        {
            let mut seqs = self.seqs.lock().unwrap_or_else(|e| e.into_inner());
            for ((res, entry), slot) in results.into_iter().zip(entries).zip(slots) {
                if let Some(e) = entry {
                    seqs.insert(slot.id, e);
                }
                match res {
                    Ok(row) => rows.push(row),
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                        rows.push(vec![]);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(rows),
        }
    }

    fn prefill_chunk(&self, slot: &DecodeSlot, max_tokens: usize) -> Result<usize> {
        if !self.opts.use_cache || max_tokens == 0 {
            return Ok(0);
        }
        let want = slot.window();
        if want.len() <= 1 {
            return Ok(0);
        }
        // prefill_chunk runs on the scheduler thread between steps, so
        // the map access is uncontended; the entry still comes out of
        // (and always goes back into) the registry, same as step()
        let mut entry = self
            .seqs
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&slot.id)
            .unwrap_or_else(|| SlotCache {
                kv: KvSeq::new(self.layout),
                history: Vec::new(),
                scratch: RowScratch::new(),
            });
        let result = self.prefill_chunk_entry(want, max_tokens, &mut entry);
        self.seqs.lock().unwrap_or_else(|e| e.into_inner()).insert(slot.id, entry);
        result
    }

    fn release(&self, slot: &DecodeSlot) {
        let entry = self.seqs.lock().unwrap_or_else(|e| e.into_inner()).remove(&slot.id);
        if let Some(mut e) = entry {
            e.kv.clear(&mut self.pool.lock().unwrap_or_else(|e| e.into_inner()));
        }
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        let mut stats = CacheStats {
            kv_pages_hwm: self.kv_high_water() as u64,
            ..CacheStats::default()
        };
        if let Some(p) = self.prefix_stats() {
            stats.prefix_lookups = p.lookups;
            stats.prefix_hits = p.hits;
            stats.prefix_hit_tokens = p.hit_tokens;
            stats.prefix_pages = p.stored_pages as u64;
        }
        Some(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batch::{decode_step, generate_greedy};
    use crate::train::ParamStore;

    fn nano_backend(use_cache: bool) -> NativeBackend {
        let m = preset::native_manifest("nano").unwrap();
        let fp = ParamStore::init(&m, 42);
        let store =
            preset::quantize_store(&m, &fp, crate::formats::codec::FormatKind::Nvfp4).unwrap();
        let model = NativeModel::new(&m.config, &store, true).unwrap();
        assert_eq!(model.n_packed(), 7);
        assert!(model.packed_payload_bytes() > 0);
        NativeBackend::new(model, NativeOptions { use_cache, ..NativeOptions::default() })
    }

    #[test]
    fn cached_decode_matches_uncached_exactly() {
        let cached = nano_backend(true);
        let plain = nano_backend(false);
        for (prompt, n) in [(vec![1, 2, 3], 12usize), (vec![200, 7], 8), (vec![5], 20)] {
            let a = generate_greedy(&cached, &prompt, n).unwrap();
            let b = generate_greedy(&plain, &prompt, n).unwrap();
            assert_eq!(a, b, "cached vs uncached diverged for {prompt:?}");
            assert_eq!(a.len(), n);
            assert!(a.iter().all(|&t| t >= 0 && t < 256));
        }
        // all pages released once every greedy decode finished
        assert_eq!(cached.kv_outstanding(), 0);
        assert_eq!(cached.cached_slots(), 0);
    }

    #[test]
    fn batched_decode_matches_sequential() {
        let backend = nano_backend(true);
        let prompts: Vec<Vec<i32>> = (0..5).map(|i| vec![i * 17 + 1, i + 2, 40 - i]).collect();
        // varying budgets: short slots finish early and ride along done
        // (decode_step keeps them in the batch; their rows are skipped)
        let budget = |i: usize| 6 + 2 * i;
        let sequential: Vec<Vec<i32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| generate_greedy(&backend, p, budget(i)).unwrap())
            .collect();
        let mut slots: Vec<DecodeSlot> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| DecodeSlot::new(p, budget(i), backend.seq_len()).unwrap())
            .collect();
        while slots.iter().any(|s| !s.done()) {
            decode_step(&backend, &mut slots).unwrap();
        }
        for (slot, expect) in slots.iter().zip(&sequential) {
            assert_eq!(&slot.out, expect, "batched native decode diverged");
            backend.release(slot);
        }
        assert_eq!(backend.kv_outstanding(), 0);
    }

    #[test]
    fn sampled_native_decode_reproducible_and_batch_invariant() {
        use crate::serve::batch::generate;
        use crate::serve::sampling::GenParams;
        let backend = nano_backend(true);
        let params = |i: u64| GenParams {
            temperature: 0.8,
            top_p: 0.9,
            seed: 123 + i,
            ..GenParams::default()
        };
        // seeded sampling is reproducible across runs on the native path
        let a = generate(&backend, &[1, 2, 3], 8, params(0)).unwrap();
        let b = generate(&backend, &[1, 2, 3], 8, params(0)).unwrap();
        assert_eq!(a, b, "seeded sampled decode must reproduce");
        // and batch composition cannot perturb a sampled request either
        let prompts: Vec<Vec<i32>> = (0..3).map(|i| vec![i * 31 + 1, i + 2]).collect();
        let sequential: Vec<Vec<i32>> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| generate(&backend, p, 6, params(i as u64)).unwrap())
            .collect();
        let mut slots: Vec<DecodeSlot> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                DecodeSlot::with_params(p, 6, backend.seq_len(), params(i as u64)).unwrap()
            })
            .collect();
        while slots.iter().any(|s| !s.done()) {
            decode_step(&backend, &mut slots).unwrap();
        }
        for (slot, expect) in slots.iter().zip(&sequential) {
            assert_eq!(&slot.out, expect, "sampled native batched decode diverged");
            backend.release(slot);
        }
        assert_eq!(backend.kv_outstanding(), 0);
    }

    #[test]
    fn logits_window_deterministic_and_validated() {
        let backend = nano_backend(true);
        let model = backend.model();
        let a = model.logits_window(&[3, 5, 7]).unwrap();
        let b = model.logits_window(&[3, 5, 7]).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 256);
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(model.logits_window(&[]).is_err());
        assert!(model.logits_window(&[999]).is_err());
        assert!(model.logits_window(&[1; 65]).is_err());
    }

    #[test]
    fn prefill_bit_identical_to_token_by_token_window() {
        // the tentpole parity: the batched [T, ·] prefill path must
        // reproduce the token-by-token reference EXACTLY, for every
        // format and with activation quant both on and off
        for format in [
            crate::formats::codec::FormatKind::Nvfp4,
            crate::formats::codec::FormatKind::Mxfp4,
            crate::formats::codec::FormatKind::E2m1,
        ] {
            let m = preset::native_manifest("nano").unwrap();
            let fp = ParamStore::init(&m, 42);
            let store = preset::quantize_store(&m, &fp, format).unwrap();
            for act_quant in [true, false] {
                let model = NativeModel::new(&m.config, &store, act_quant).unwrap();
                for prompt in [
                    vec![3, 5, 7],
                    vec![1],
                    (0..64).map(|i| (i * 3 % 256) as i32).collect::<Vec<i32>>(),
                ] {
                    let reference = model.logits_window(&prompt).unwrap();
                    let fast = model.prefill(&prompt).unwrap();
                    assert_eq!(
                        fast,
                        reference,
                        "{} act_quant={act_quant}: prefill diverged for {} tokens",
                        format.name(),
                        prompt.len()
                    );
                    // scalar column budget must agree too
                    let scalar = model.prefill_paged(&prompt, 8, KvFormat::F32, 1).unwrap();
                    assert_eq!(scalar, reference, "scalar prefill diverged");
                }
            }
        }
    }

    #[test]
    fn prefill_validates_like_logits_window() {
        let backend = nano_backend(true);
        let model = backend.model();
        assert!(model.prefill(&[]).is_err());
        assert!(model.prefill(&[999]).is_err());
        assert!(model.prefill(&[-1]).is_err());
        assert!(model.prefill(&[1; 65]).is_err());
    }

    #[test]
    fn logits_window_page_size_never_changes_logits() {
        let backend = nano_backend(true);
        let model = backend.model();
        let reference = model.logits_window(&[9, 8, 7, 6]).unwrap();
        for page_tokens in [1usize, 3, 16, 64] {
            let got = model
                .logits_window_paged(
                    &[9, 8, 7, 6],
                    page_tokens,
                    KvFormat::F32,
                    threads::default_workers(),
                )
                .unwrap();
            assert_eq!(got, reference, "page_tokens={page_tokens} changed the logits");
        }
    }

    fn cosine(a: &[f32], b: &[f32]) -> f64 {
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for (&x, &y) in a.iter().zip(b) {
            dot += x as f64 * y as f64;
            na += x as f64 * x as f64;
            nb += y as f64 * y as f64;
        }
        dot / (na.sqrt() * nb.sqrt()).max(1e-30)
    }

    fn argmax(v: &[f32]) -> usize {
        let mut best = 0;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        best
    }

    #[test]
    fn e4m3_kv_close_to_f32_kv_over_multi_page_sequence() {
        // the documented tolerance for the one deliberately non-bit-exact
        // path: e4m3-cached logits stay cosine >= 0.999 to f32-cached
        // logits and pick the same greedy token, over a window that spans
        // several pages (page_tokens=4, 13 tokens -> 4 pages)
        let backend = nano_backend(true);
        let model = backend.model();
        let prompt: Vec<i32> = (0..13).map(|i| (i * 19 + 3) % 256).collect();
        for t in 2..=prompt.len() {
            let f32_logits =
                model.logits_window_paged(&prompt[..t], 4, KvFormat::F32, 1).unwrap();
            let e4m3_logits =
                model.logits_window_paged(&prompt[..t], 4, KvFormat::E4m3, 1).unwrap();
            let cos = cosine(&f32_logits, &e4m3_logits);
            assert!(cos >= 0.999, "t={t}: e4m3 kv cosine {cos} below tolerance");
            // the full multi-page window must also pick the same greedy
            // token, and the quantization must actually be live
            if t == prompt.len() {
                assert_eq!(
                    argmax(&f32_logits),
                    argmax(&e4m3_logits),
                    "e4m3 kv flipped the greedy token on the full window"
                );
                assert_ne!(f32_logits, e4m3_logits, "e4m3 kv path identical to f32?");
            }
        }
    }

    #[test]
    fn e4m3_kv_cached_decode_matches_uncached_exactly() {
        // cached==uncached stays BIT-exact within the e4m3 format: both
        // paths quantize the same rows through the same codec, so the
        // pool-exhaustion fallback can never change tokens mid-stream
        let mk = |use_cache: bool| {
            let m = preset::native_manifest("nano").unwrap();
            let fp = ParamStore::init(&m, 42);
            let store =
                preset::quantize_store(&m, &fp, crate::formats::codec::FormatKind::Nvfp4)
                    .unwrap();
            let model = NativeModel::new(&m.config, &store, true).unwrap();
            NativeBackend::new(
                model,
                NativeOptions {
                    use_cache,
                    kv_format: KvFormat::E4m3,
                    page_tokens: 4,
                    ..NativeOptions::default()
                },
            )
        };
        let cached = mk(true);
        let plain = mk(false);
        for (prompt, n) in [(vec![1, 2, 3], 12usize), (vec![200, 7], 8)] {
            let a = generate_greedy(&cached, &prompt, n).unwrap();
            let b = generate_greedy(&plain, &prompt, n).unwrap();
            assert_eq!(a, b, "e4m3 cached vs uncached diverged for {prompt:?}");
        }
        assert_eq!(cached.kv_outstanding(), 0);
    }

    #[test]
    fn pool_exhaustion_falls_back_not_fails() {
        // a pool too small for even one slot's window: every step falls
        // back to uncached compute, and output still matches the
        // reference exactly
        let m = preset::native_manifest("nano").unwrap();
        let fp = ParamStore::init(&m, 42);
        let store =
            preset::quantize_store(&m, &fp, crate::formats::codec::FormatKind::Nvfp4).unwrap();
        let model = NativeModel::new(&m.config, &store, true).unwrap();
        let tiny_pool = NativeBackend::new(
            model.clone(),
            NativeOptions { max_pages: 1, page_tokens: 4, ..NativeOptions::default() },
        );
        let reference = NativeBackend::new(model, NativeOptions::default());
        let a = generate_greedy(&tiny_pool, &[9, 8, 7, 6, 5], 10).unwrap();
        let b = generate_greedy(&reference, &[9, 8, 7, 6, 5], 10).unwrap();
        assert_eq!(a, b);
        assert_eq!(tiny_pool.kv_outstanding(), 0);
    }

    fn nano_backend_with(opts: NativeOptions) -> NativeBackend {
        let m = preset::native_manifest("nano").unwrap();
        let fp = ParamStore::init(&m, 42);
        let store =
            preset::quantize_store(&m, &fp, crate::formats::codec::FormatKind::Nvfp4).unwrap();
        let model = NativeModel::new(&m.config, &store, true).unwrap();
        NativeBackend::new(model, opts)
    }

    #[test]
    fn prefix_cache_hits_bit_identical_and_leak_free() {
        for kv_format in [KvFormat::F32, KvFormat::E4m3] {
            let shared = nano_backend_with(NativeOptions {
                prefix_cache: true,
                page_tokens: 4,
                kv_format,
                ..NativeOptions::default()
            });
            let plain = nano_backend_with(NativeOptions {
                page_tokens: 4,
                kv_format,
                ..NativeOptions::default()
            });
            // two prompts sharing an 8-token (2 full pages) prefix, plus
            // an exact repeat of the first
            let prefix = [7, 3, 9, 1, 2, 4, 6, 8];
            let mut a = prefix.to_vec();
            a.extend_from_slice(&[11, 12]);
            let mut b = prefix.to_vec();
            b.extend_from_slice(&[33]);
            for prompt in [&a, &b, &a] {
                let hit = generate_greedy(&shared, prompt, 8).unwrap();
                let cold = generate_greedy(&plain, prompt, 8).unwrap();
                assert_eq!(hit, cold, "{}: cache-hit tokens diverged", kv_format.name());
            }
            let stats = shared.prefix_stats().expect("prefix cache enabled");
            assert!(stats.lookups >= 3, "one lookup per admission, got {}", stats.lookups);
            assert!(stats.hits >= 2, "later prompts must hit, got {}", stats.hits);
            assert!(stats.hit_tokens >= 16, "2 pages x 2 hits, got {}", stats.hit_tokens);
            assert!(stats.stored_pages > 0);
            // slots drained; only the trie still holds pages — and a
            // clear returns every one of them
            assert_eq!(shared.cached_slots(), 0);
            assert_eq!(shared.kv_outstanding(), stats.stored_pages);
            shared.clear_prefix_cache();
            assert_eq!(shared.kv_outstanding(), 0, "{}: trie leaked pages", kv_format.name());
            assert!(shared.kv_high_water() > 0);
        }
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        // feeding a long prompt in 4-token chunks (through the trie as
        // well) must leave the slot producing exactly the tokens a
        // one-shot prefill would
        let backend = nano_backend_with(NativeOptions {
            prefix_cache: true,
            page_tokens: 4,
            ..NativeOptions::default()
        });
        let reference = nano_backend(true);
        let prompt: Vec<i32> = (0..30).map(|i| (i * 13 + 5) % 256).collect();
        let expect = generate_greedy(&reference, &prompt, 10).unwrap();
        let mut slots = vec![DecodeSlot::new(&prompt, 10, backend.seq_len()).unwrap()];
        let mut chunks = 0;
        loop {
            let missing = backend.prefill_chunk(&slots[0], 4).unwrap();
            chunks += 1;
            assert!(chunks < 100, "chunked prefill failed to converge");
            if missing == 0 {
                break;
            }
        }
        assert!(chunks > 1, "a 30-token prompt must take several 4-token chunks");
        while !slots[0].done() {
            decode_step(&backend, &mut slots).unwrap();
        }
        assert_eq!(slots[0].out, expect, "chunked prefill changed the tokens");
        backend.release(&slots[0]);
        assert_eq!(backend.cached_slots(), 0);
        backend.clear_prefix_cache();
        assert_eq!(backend.kv_outstanding(), 0);
    }

    #[test]
    fn prefix_cache_evicts_under_pool_pressure() {
        // a pool too small to keep every published prefix: admission
        // evicts LRU trie pages (or falls back to uncached compute) and
        // tokens never change
        let tight = nano_backend_with(NativeOptions {
            prefix_cache: true,
            page_tokens: 4,
            max_pages: 5,
            ..NativeOptions::default()
        });
        let plain = nano_backend(true);
        for seed in 0..4 {
            let prompt: Vec<i32> = (0..10).map(|i| (i * 7 + seed * 41 + 1) % 256).collect();
            let a = generate_greedy(&tight, &prompt, 6).unwrap();
            let b = generate_greedy(&plain, &prompt, 6).unwrap();
            assert_eq!(a, b, "seed {seed}: eviction path changed tokens");
        }
        let stats = tight.prefix_stats().unwrap();
        assert!(stats.stored_pages <= 5, "trie grew past the pool cap");
        tight.clear_prefix_cache();
        assert_eq!(tight.kv_outstanding(), 0);
    }

    #[test]
    fn act_quant_changes_logits_but_stays_deterministic() {
        let m = preset::native_manifest("nano").unwrap();
        let fp = ParamStore::init(&m, 42);
        let store =
            preset::quantize_store(&m, &fp, crate::formats::codec::FormatKind::Nvfp4).unwrap();
        let w4a4 = NativeModel::new(&m.config, &store, true).unwrap();
        let w4a16 = NativeModel::new(&m.config, &store, false).unwrap();
        assert!(w4a4.act_quant());
        assert!(!w4a16.act_quant());
        let a = w4a4.logits_window(&[1, 2, 3]).unwrap();
        let b = w4a16.logits_window(&[1, 2, 3]).unwrap();
        assert_ne!(a, b, "activation quantization must be live");
    }
}
