//! Result tables: paper-format printers + CSV/JSON writers.
//!
//! Every `faar tables --id tN` harness builds a [`Table`], prints it in
//! the paper's row/column layout, and persists it under `results/` so
//! EXPERIMENTS.md can quote it verbatim.

pub mod tables;

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug)]
/// One paper-style result table: labeled rows of optional values.
pub struct Table {
    /// table caption
    pub title: String,
    /// column headers
    pub columns: Vec<String>,
    /// labeled rows (`None` renders as an em dash)
    pub rows: Vec<(String, Vec<Option<f64>>)>,
    /// printf precision per value
    pub precision: usize,
}

impl Table {
    /// An empty table with the given caption and columns.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            precision: 2,
        }
    }

    /// Append a row (width-checked against the columns).
    pub fn row(&mut self, label: &str, values: Vec<Option<f64>>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    /// Append a row of plain values.
    pub fn row_f(&mut self, label: &str, values: &[f64]) {
        self.row(label, values.iter().map(|&v| Some(v)).collect());
    }

    /// Paper-style fixed-width text rendering.
    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(6))
            .max()
            .unwrap()
            + 2;
        let col_w = self.columns.iter().map(|c| c.len().max(8) + 2).collect::<Vec<_>>();
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!("{:<label_w$}", "method"));
        for (c, w) in self.columns.iter().zip(&col_w) {
            out.push_str(&format!("{c:>w$}"));
        }
        out.push('\n');
        out.push_str(&"-".repeat(label_w + col_w.iter().sum::<usize>()));
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("{label:<label_w$}"));
            for (v, w) in vals.iter().zip(&col_w) {
                match v {
                    Some(x) => out.push_str(&format!("{x:>w$.prec$}", prec = self.precision)),
                    None => out.push_str(&format!("{:>w$}", "—")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// CSV rendering (empty cells for `None`).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str("method");
        for c in &self.columns {
            out.push(',');
            out.push_str(c);
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(label);
            for v in vals {
                out.push(',');
                if let Some(x) = v {
                    out.push_str(&format!("{x:.6}"));
                }
            }
            out.push('\n');
        }
        out
    }

    /// JSON rendering (title, columns, rows).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.as_str())),
            (
                "columns",
                Json::Arr(self.columns.iter().map(|c| Json::str(c.as_str())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|(l, vs)| {
                            Json::obj(vec![
                                ("label", Json::str(l.as_str())),
                                (
                                    "values",
                                    Json::Arr(
                                        vs.iter()
                                            .map(|v| match v {
                                                Some(x) => Json::Num(*x),
                                                None => Json::Null,
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Print to stdout and write .csv + .json under `dir/<stem>.*`.
    pub fn emit(&self, dir: &Path, stem: &str) -> Result<()> {
        println!("{}", self.render());
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv())?;
        std::fs::write(dir.join(format!("{stem}.json")), self.to_json().to_string_pretty())?;
        println!("→ wrote {}/{stem}.csv", dir.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Test", &["wiki", "c4"]);
        t.row_f("rtn", &[14.28, 36.19]);
        t.row("gptq", vec![Some(13.74), None]);
        t
    }

    #[test]
    fn render_contains_values() {
        let r = sample().render();
        assert!(r.contains("14.28"));
        assert!(r.contains("rtn"));
        assert!(r.contains("—"));
    }

    #[test]
    fn csv_shape() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "method,wiki,c4");
        assert!(lines[2].ends_with(','));
    }

    #[test]
    fn json_roundtrip() {
        let j = sample().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("title").unwrap().as_str().unwrap(), "Test");
        assert_eq!(parsed.req("rows").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row_f("r", &[1.0, 2.0]);
    }
}
