//! Paper-table reproduction harnesses (DESIGN.md §6 experiment index).
//!
//! Each `table_*` function regenerates one table of the paper on the
//! synthetic substrate: same rows, same metric, same expected *shape*
//! (method ordering / deltas), absolute numbers differ by design.
//! `figure2` emits the CSV series behind Figure 2.

use std::path::Path;

use anyhow::Result;

use crate::config::PipelineConfig;
use crate::data::tasks::TaskKind;
use crate::formats::{e2m1, nvfp4};
use crate::pipeline::{Method, Workbench};
use crate::tensor::Tensor;
use crate::util::{rng::Rng, stats};

use super::Table;

/// Table 1: rounding-scheme study (RTN vs lower/upper/stochastic).
pub fn table1(wb: &Workbench, n_trials: usize) -> Result<Table> {
    let mut t = Table::new(
        &format!("Table 1 — rounding schemes, {} on synthwiki (PPL ↓)", wb.cfg.model),
        &["ppl"],
    );
    for m in [Method::Rtn, Method::Lower, Method::Upper] {
        let out = wb.quantize(m)?;
        let label = if m == Method::Rtn { "baseline (rtn)" } else { &m.name() };
        t.row_f(label, &[wb.ppl(&out, "wiki")?]);
    }
    let mut ppls = Vec::with_capacity(n_trials);
    for trial in 0..n_trials {
        let out = wb.quantize(Method::Stochastic(trial as u64 + 1))?;
        let p = wb.ppl(&out, "wiki")?;
        crate::info!("stochastic trial {trial}: ppl {p:.3}");
        ppls.push(p);
    }
    t.row_f("stochastic (mean)", &[stats::mean(&ppls)]);
    t.row_f("stochastic (std)", &[stats::std_dev(&ppls)]);
    t.row_f("stochastic (best)", &[stats::min(&ppls)]);
    t.precision = 3;
    Ok(t)
}

/// The method list of Tables 3/4 in paper order.
pub fn main_methods() -> Vec<Method> {
    vec![
        Method::Bf16,
        Method::Rtn,
        Method::Gptq,
        Method::MrGptq,
        Method::FourSix,
        Method::GptqFourSix,
        Method::StrongBaseline,
        Method::Faar2fa,
    ]
}

/// Tables 3 + 4 for one model: PPL and cosine on both corpora.
/// Returns (table3, table4).
pub fn table3_4(wb: &Workbench, methods: &[Method]) -> Result<(Table, Table)> {
    let cols = ["synthwiki", "synthc4"];
    let mut t3 = Table::new(
        &format!("Table 3 — word PPL (↓), model {}", wb.cfg.model),
        &cols,
    );
    let mut t4 = Table::new(
        &format!("Table 4 — last-hidden cosine similarity %, model {}", wb.cfg.model),
        &cols,
    );
    for &m in methods {
        let out = wb.quantize(m)?;
        let mut ppls = vec![];
        let mut coss = vec![];
        for c in cols {
            let lm = wb.lm_metrics(&out, c)?;
            ppls.push(lm.ppl);
            coss.push(lm.cosine_pct);
        }
        crate::info!(
            "{}: wiki ppl {:.3} cos {:.2}% | c4 ppl {:.3} cos {:.2}% ({:.0}s)",
            m.name(), ppls[0], coss[0], ppls[1], coss[1], out.wall_s
        );
        t3.row_f(&m.name(), &ppls);
        t4.row_f(&m.name(), &coss);
    }
    t3.precision = 3;
    t4.precision = 2;
    Ok((t3, t4))
}

/// Table 5: zero-shot probe accuracy (%).
pub fn table5(wb: &Workbench, methods: &[Method], n_probes: usize) -> Result<Table> {
    let kinds = TaskKind::all();
    let mut cols: Vec<&str> = kinds.iter().map(|k| k.name()).collect();
    cols.push("average");
    let mut t = Table::new(
        &format!("Table 5 — zero-shot accuracy %, model {}", wb.cfg.model),
        &cols,
    );
    for &m in methods {
        let out = wb.quantize(m)?;
        let mut accs = vec![];
        for k in kinds {
            accs.push(wb.task_accuracy(&out, k, n_probes)?);
        }
        accs.push(stats::mean(&accs));
        crate::info!("{}: {:?}", m.name(), accs);
        t.row_f(&m.name(), &accs);
    }
    t.precision = 2;
    Ok(t)
}

/// Table 6: component ablation (RTN → FAAR → FAAR+2FA).
pub fn table6(wb: &Workbench) -> Result<Table> {
    let mut t = Table::new(
        &format!("Table 6 — component ablation, {} on synthwiki (PPL ↓)", wb.cfg.model),
        &["ppl"],
    );
    for m in [Method::Bf16, Method::Rtn, Method::Faar, Method::Faar2fa] {
        let out = wb.quantize(m)?;
        let p = wb.ppl(&out, "wiki")?;
        crate::info!("{}: ppl {p:.3}", m.name());
        t.row_f(&m.name(), &[p]);
    }
    t.precision = 3;
    Ok(t)
}

/// Table 7: stage-2 optimization-steps sweep. Runs stage-1 once, then a
/// single stage-2 pass with snapshots at each checkpoint.
pub fn table7(wb: &Workbench, checkpoints: &[usize]) -> Result<Table> {
    use crate::pipeline::{faar, harden};
    let mut t = Table::new(
        &format!("Table 7 — stage-2 steps, {} on synthwiki (PPL ↓)", wb.cfg.model),
        &["ppl"],
    );
    let max = *checkpoints.iter().max().unwrap();
    let mut state = faar::prepare_all(&wb.rt, &wb.fp, &wb.cfg)?;
    faar::stage1(&wb.rt, &wb.fp, &wb.calib, &wb.cfg, &mut state)?;

    for (i, &ck) in checkpoints.iter().enumerate() {
        let prev = if i == 0 { 0 } else { checkpoints[i - 1] };
        let delta = ck - prev;
        if delta > 0 {
            let mut cfg = wb.cfg.clone();
            cfg.stage2_steps = delta;
            faar::stage2(&wb.rt, &wb.fp, &[&wb.wiki, &wb.c4], &cfg, &mut state)?;
        }
        let params = harden::harden_to_params(&wb.rt, &wb.fp, &state)?;
        let out = crate::pipeline::QuantOutcome {
            params,
            method: Method::Faar2fa,
            wall_s: 0.0,
            faar: None,
        };
        let p = wb.ppl(&out, "wiki")?;
        crate::info!("steps {ck}: ppl {p:.3}");
        t.row_f(&format!("{ck}"), &[p]);
    }
    let _ = max;
    t.precision = 3;
    Ok(t)
}

/// Table 8: stage-2 learning-rate sweep.
pub fn table8(wb: &Workbench, lrs: &[f32]) -> Result<Table> {
    use crate::pipeline::{faar, harden};
    let mut t = Table::new(
        &format!("Table 8 — stage-2 learning rate, {} on synthwiki (PPL ↓)", wb.cfg.model),
        &["ppl"],
    );
    // share the stage-1 result across the sweep
    let mut base = faar::prepare_all(&wb.rt, &wb.fp, &wb.cfg)?;
    faar::stage1(&wb.rt, &wb.fp, &wb.calib, &wb.cfg, &mut base)?;
    let v1: Vec<(String, Tensor)> =
        base.v.iter().map(|(k, v)| (k.clone(), v.clone())).collect();

    for &lr in lrs {
        for (k, v) in &v1 {
            base.v.insert(k.clone(), v.clone());
        }
        base.stage2_log.clear();
        let mut cfg = wb.cfg.clone();
        cfg.stage2_lr = lr;
        faar::stage2(&wb.rt, &wb.fp, &[&wb.wiki, &wb.c4], &cfg, &mut base)?;
        let params = harden::harden_to_params(&wb.rt, &wb.fp, &base)?;
        let out = crate::pipeline::QuantOutcome {
            params,
            method: Method::Faar2fa,
            wall_s: 0.0,
            faar: None,
        };
        let p = wb.ppl(&out, "wiki")?;
        crate::info!("lr {lr:.0e}: ppl {p:.3}");
        t.row_f(&format!("{lr:.0e}"), &[p]);
    }
    t.precision = 3;
    Ok(t)
}

/// Figure 2: the NVFP4 mapping curve and absolute rounding error, as CSV
/// (w, mapped, abs_err) plus the per-magnitude expected error of a
/// Gaussian weight population.
pub fn figure2(dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut csv = String::from("w,mapped,abs_err\n");
    let steps = 1400;
    for i in 0..=steps {
        let w = 7.0 * i as f32 / steps as f32;
        let mapped = e2m1::decode(e2m1::encode_rtn(w));
        csv.push_str(&format!("{w:.4},{mapped:.4},{:.5}\n", (mapped - w).abs()));
    }
    std::fs::write(dir.join("figure2_mapping.csv"), &csv)?;

    // panel (b): quantization error of a Gaussian tensor vs magnitude
    let mut rng = Rng::new(2);
    let mut w = Tensor::zeros(&[4096, 16]);
    rng.fill_normal(&mut w.data, 0.0, 1.0);
    let p = nvfp4::prepare(&w);
    let q = nvfp4::rtn_quant(&w, &p);
    let mut csv2 = String::from("abs_w,abs_err\n");
    for i in 0..w.numel() {
        csv2.push_str(&format!(
            "{:.4},{:.6}\n",
            w.data[i].abs(),
            (q.data[i] - w.data[i]).abs()
        ));
    }
    std::fs::write(dir.join("figure2_error_scatter.csv"), &csv2)?;
    println!("→ wrote {}/figure2_mapping.csv and figure2_error_scatter.csv", dir.display());
    Ok(())
}

/// Default pipeline-config tweaks for sweep-heavy tables so the full run
/// stays tractable on CPU; callers can override via CLI.
pub fn sweep_config(base: &PipelineConfig) -> PipelineConfig {
    let mut c = base.clone();
    c.stage1_steps = base.stage1_steps.min(150);
    c.stage2_steps = base.stage2_steps.min(120);
    c
}

/// Format ablation (extension — DESIGN.md §6 footnote): NVFP4's
/// 16-element E4M3 block scales vs MXFP4's 32-element power-of-two
/// scales, on the same checkpoint. Both rows are one `Method` through
/// the unified `FormatCodec` registry — weight MSE, end-task PPL, and
/// the real packed bits/weight each format pays for its scales.
pub fn format_ablation(wb: &Workbench) -> Result<Table> {
    let mut t = Table::new(
        &format!(
            "Format ablation — NVFP4 vs MXFP4, model {} (weight MSE / PPL ↓ / bits per weight)",
            wb.cfg.model
        ),
        &["weight_mse", "wiki_ppl", "c4_ppl", "bits_per_w"],
    );

    let weight_mse = |out: &crate::pipeline::QuantOutcome| -> Result<f64> {
        let mut acc = 0.0;
        let mut n = 0usize;
        for q in &wb.rt.manifest.qlinears {
            let a = wb.fp.get(&q.name)?;
            let b = out.params.get(&q.name)?;
            acc += stats::mse(&a.data, &b.data) * a.data.len() as f64;
            n += a.data.len();
        }
        Ok(acc / n as f64)
    };

    let mut mses = vec![];
    for (label, m) in [("nvfp4 (rtn)", Method::Rtn), ("mxfp4 (rtn)", Method::Mxfp4)] {
        let out = wb.quantize(m)?;
        let mse = weight_mse(&out)?;
        let bits = out.params.packed_payload_bytes() as f64 * 8.0
            / (out.params.packed_dense_bytes() / 4).max(1) as f64;
        t.row_f(label, &[mse, wb.ppl(&out, "wiki")?, wb.ppl(&out, "c4")?, bits]);
        mses.push(mse);
    }
    t.precision = 4;
    crate::info!("format ablation: nvfp4 mse {:.3e} vs mxfp4 {:.3e}", mses[0], mses[1]);
    Ok(t)
}
