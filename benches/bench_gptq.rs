//! GPTQ substrate: Hessian accumulation, Cholesky/inverse, and the full
//! column solve at the layer shapes the tiny/small presets use.

use nvfp4_faar::formats::nvfp4;
use nvfp4_faar::gptq::{cholesky, gptq_quantize, spd_inverse, GptqOptions, Hessian};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::rng::Rng;

fn rand_t(shape: &[usize], seed: u64, std: f32) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 0.0, std);
    t
}

fn main() {
    let mut b = Bench::new("gptq");

    for k in [128usize, 352] {
        let x = rand_t(&[512, k], 1, 1.0);
        b.bench_n(&format!("hessian_update_512x{k}"), (512 * k) as u64, || {
            let mut h = Hessian::new(k);
            h.update(&x).unwrap();
            black_box(h.n_rows);
        });

        let mut h = Hessian::new(k);
        h.update(&x).unwrap();
        let hd = h.damped(0.01);
        b.bench(&format!("cholesky_{k}"), || {
            black_box(cholesky(&hd, k).unwrap());
        });
        b.bench(&format!("spd_inverse_{k}"), || {
            black_box(spd_inverse(&hd, k).unwrap());
        });

        let n = if k == 128 { 128 } else { 128 };
        let w = rand_t(&[k, n], 2, 0.05);
        let p = nvfp4::prepare(&w);
        b.bench_n(&format!("gptq_solve_{k}x{n}"), (k * n) as u64, || {
            black_box(
                gptq_quantize(&w, &h, &p.scale, &p.s_global, GptqOptions::default()).unwrap(),
            );
        });
        b.bench_n(&format!("mr_gptq_solve_{k}x{n}"), (k * n) as u64, || {
            black_box(
                gptq_quantize(
                    &w,
                    &h,
                    &p.scale,
                    &p.s_global,
                    GptqOptions { mr_scales: true, ..Default::default() },
                )
                .unwrap(),
            );
        });
    }

    b.finish();
}
