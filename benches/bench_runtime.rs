//! Runtime / artifact benches: compile cost, forward latency + token
//! throughput, stage-1 step latency, and the Pallas-vs-jnp kernel cost
//! through the real PJRT path. Needs `make artifacts` (nano).

use std::path::Path;

use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::rng::Rng;

fn main() {
    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return;
    }
    let mut b = Bench::new("runtime");
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    let cfg = rt.config().clone();
    let params = ParamStore::init(&rt.manifest, 42);

    b.bench("compile_lm_fwd_cold", || {
        // cold compile: fresh runtime each iteration (compile cache is
        // per-Runtime)
        let rt2 = Runtime::load(Path::new("artifacts"), "nano").unwrap();
        black_box(rt2.executable("lm_fwd").unwrap());
    });

    // eval forward: latency + throughput
    let mut rng = Rng::new(1);
    let toks: Vec<i32> =
        (0..cfg.eval_batch * (cfg.seq_len + 1)).map(|_| rng.below(cfg.vocab) as i32).collect();
    let tokens = Value::I32(toks, vec![cfg.eval_batch, cfg.seq_len + 1]);
    let mut args = params.values();
    args.push(tokens);
    rt.warmup(&["lm_fwd", "lm_fwd_aq"]).unwrap();
    let n_tok = (cfg.eval_batch * cfg.seq_len) as u64;
    b.bench_n("lm_fwd_exec", n_tok, || {
        black_box(rt.exec("lm_fwd", &args).unwrap());
    });
    b.bench_n("lm_fwd_aq_exec", n_tok, || {
        black_box(rt.exec("lm_fwd_aq", &args).unwrap());
    });

    // stage-1 step (the FAAR hot loop)
    let d = cfg.d_model;
    let name = format!("stage1_step_{d}x{d}");
    let mut w = Tensor::zeros(&[d, d]);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    let p = nvfp4_faar::formats::nvfp4::prepare(&w);
    let mut x = Tensor::zeros(&[cfg.stage1_rows, d]);
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    let s1_args = vec![
        Value::F32(x),
        Value::F32(w),
        Value::F32(p.lower.clone()),
        Value::F32(p.upper.clone()),
        Value::F32(p.scale.clone()),
        Value::F32(p.v_init.clone()),
        Value::F32(Tensor::zeros(&[d, d])),
        Value::F32(Tensor::zeros(&[d, d])),
        Value::scalar_f32(1.0),
        Value::scalar_f32(10.0),
        Value::scalar_f32(1e-2),
        Value::scalar_f32(1e-2),
    ];
    rt.warmup(&[&name]).unwrap();
    b.bench(&format!("{name}_exec"), || {
        black_box(rt.exec(&name, &s1_args).unwrap());
    });

    // kernel: pallas interpret vs jnp lowering, same math
    let kargs = vec![
        s1_args[1].clone(),
        Value::F32(p.lower),
        Value::F32(p.upper),
        Value::F32(p.scale),
        Value::F32(p.v_init),
        Value::scalar_f32(10.0),
    ];
    rt.warmup(&["kernel_softquant", "kernel_softquant_jnp"]).unwrap();
    b.bench(&format!("kernel_softquant_pallas_{d}x{d}"), || {
        black_box(rt.exec("kernel_softquant", &kargs).unwrap());
    });
    b.bench(&format!("kernel_softquant_jnp_{d}x{d}"), || {
        black_box(rt.exec("kernel_softquant_jnp", &kargs).unwrap());
    });

    b.finish();
}
