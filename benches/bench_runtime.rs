//! Runtime / artifact benches: compile cost, forward latency + token
//! throughput, stage-1 step latency, and the Pallas-vs-jnp kernel cost
//! through the real PJRT path (needs `make artifacts`, nano) — plus two
//! artifact-free benches that run everywhere:
//!
//! * a synthetic serving load-generator measuring the concurrent batched
//!   engine end-to-end over TCP → `BENCH_serve.json` (p50/p95/p99 +
//!   tokens/sec at micro-batch 1/4/16, plus a mixed-load scenario where
//!   a 4k-token prompt lands mid-stream of 8 decoding clients and the
//!   chunked-prefill scheduler must improve p99 inter-token latency by
//!   ≥2x — asserted, `FAAR_BENCH_TOLERANT` downgrades to a note, plus
//!   an overload scenario where pipelined bursts past capacity must be
//!   shed by `max_queue_wait_ms` admission control for a ≥2x accepted
//!   p99 improvement — same floor discipline), and
//! * the NATIVE pure-rust backend's decode throughput at batch 1/4/16
//!   with and without the paged KV cache → `BENCH_native.json` (the KV
//!   cache must clear ≥2x at a 256-token window — asserted here, not
//!   just recorded).

use std::net::{SocketAddr, TcpListener};
use std::path::Path;
use std::time::{Duration, Instant};

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::preset::{manifest_from_config, native_config};
use nvfp4_faar::infer::{quantize_store, NativeBackend, NativeModel, NativeOptions};
use nvfp4_faar::runtime::{Runtime, Value};
use nvfp4_faar::serve::batch::{decode_step, DecodeSlot, StepBackend};
use nvfp4_faar::serve::client::{Client, ClientRequest};
use nvfp4_faar::serve::{
    serve_on, ModelEntry, ModelRegistry, ServeOptions, SpecDecoder, SyntheticBackend,
};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::json::Json;
use nvfp4_faar::util::rng::Rng;
use nvfp4_faar::util::stats;

/// One load-generator client: ping-pong `reqs` token-id requests through
/// the typed protocol client, return per-request latencies as measured
/// by the server.
fn load_client(
    addr: SocketAddr,
    id: usize,
    reqs: usize,
    max_tokens: usize,
    vocab: usize,
) -> Vec<f64> {
    let mut client =
        Client::connect_timeout(addr, Duration::from_secs(60)).expect("connect");
    let mut latencies = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let prompt: Vec<i32> =
            (0..4).map(|j| ((id * 31 + i * 7 + j) % vocab) as i32).collect();
        let req = ClientRequest::tokens(prompt).max_tokens(max_tokens);
        let resp = client.request(&req).expect("transport").expect("server error");
        latencies.push(resp.latency_ms);
    }
    latencies
}

/// Synthetic serving load: the cost model charges a fixed per-step
/// overhead plus a small per-slot cost (the accelerator-step shape that
/// makes micro-batching pay), so tokens/sec must rise with `max_batch`.
/// Returns the `load` section of `BENCH_serve.json`.
fn bench_serve_load() -> Json {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let (n_clients, reqs, max_tokens) = if fast { (8, 4, 8) } else { (16, 8, 16) };
    let (vocab, seq_len) = (512, 64);
    let fixed = Duration::from_micros(250);
    let per_slot = Duration::from_micros(15);

    println!("serve load generator: {n_clients} clients x {reqs} reqs x {max_tokens} tokens");
    let mut runs = vec![];
    for &max_batch in &[1usize, 4, 16] {
        let backend =
            SyntheticBackend::new(vocab, seq_len, 42).with_costs(fixed, per_slot);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let opts = ServeOptions {
            max_batch,
            queue_depth: 256,
            max_tokens_cap: 64,
            ..ServeOptions::default()
        };
        let t0 = Instant::now();
        let (latencies, sched) = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|id| s.spawn(move || load_client(addr, id, reqs, max_tokens, vocab)))
                .collect();
            let sched = serve_on(&backend, listener, Some(n_clients), opts).expect("serve");
            let mut latencies = vec![];
            for h in handles {
                latencies.extend(h.join().expect("client panicked"));
            }
            (latencies, sched)
        });
        let wall = t0.elapsed().as_secs_f64();
        let total_tokens = (n_clients * reqs * max_tokens) as f64;
        let tok_s = total_tokens / wall;
        let (p50, p95, p99) = (
            stats::percentile(&latencies, 50.0),
            stats::percentile(&latencies, 95.0),
            stats::percentile(&latencies, 99.0),
        );
        println!(
            "  max_batch {max_batch:>2}: {tok_s:>8.0} tok/s  p50 {p50:>7.2} ms  \
             p95 {p95:>7.2} ms  p99 {p99:>7.2} ms  ({} steps, peak batch {})",
            sched.steps, sched.peak_batch
        );
        runs.push(Json::obj(vec![
            ("max_batch", Json::num(max_batch as f64)),
            ("tokens_per_s", Json::Num(tok_s)),
            ("p50_ms", Json::Num(p50)),
            ("p95_ms", Json::Num(p95)),
            ("p99_ms", Json::Num(p99)),
            ("steps", Json::num(sched.steps as f64)),
            ("batched_steps", Json::num(sched.batched_steps as f64)),
            ("peak_batch", Json::num(sched.peak_batch as f64)),
            ("completed", Json::num(sched.completed as f64)),
        ]));
    }
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("n_clients", Json::num(n_clients as f64)),
                ("reqs_per_client", Json::num(reqs as f64)),
                ("max_tokens", Json::num(max_tokens as f64)),
                ("fixed_cost_us", Json::num(fixed.as_micros() as f64)),
                ("per_slot_cost_us", Json::num(per_slot.as_micros() as f64)),
                ("vocab", Json::num(vocab as f64)),
                ("seq_len", Json::num(seq_len as f64)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
    ])
}

/// One registry-load client: spreads its requests across the default
/// route and both hosted models by round index, so the per-model queue
/// counters in `BENCH_serve.json` all see traffic.
fn registry_client(
    addr: SocketAddr,
    id: usize,
    reqs: usize,
    max_tokens: usize,
    vocab: usize,
) -> Vec<f64> {
    let mut client =
        Client::connect_timeout(addr, Duration::from_secs(60)).expect("connect");
    let mut latencies = Vec::with_capacity(reqs);
    for i in 0..reqs {
        let prompt: Vec<i32> =
            (0..4).map(|j| ((id * 31 + i * 7 + j) % vocab) as i32).collect();
        let mut req = ClientRequest::tokens(prompt).max_tokens(max_tokens);
        req = match (id + i) % 3 {
            0 => req, // default route: entry 0
            1 => req.model("base"),
            _ => req.model("spec"),
        };
        let resp = client.request(&req).expect("transport").expect("server error");
        latencies.push(resp.latency_ms);
    }
    latencies
}

/// Registry load: a plain model and a draft-paired model behind ONE
/// scheduler. Captures the speculative-decode counters and per-model
/// queue depths the shutdown log reports. Returns the `spec` section of
/// `BENCH_serve.json`.
fn bench_serve_spec() -> Json {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let (n_clients, reqs, max_tokens) =
        if fast { (4usize, 3usize, 8usize) } else { (8, 6, 16) };
    let (vocab, seq_len) = (512, 64);
    let fixed = Duration::from_micros(250);
    let per_slot = Duration::from_micros(15);
    let draft_fixed = Duration::from_micros(25);

    let registry = ModelRegistry::new(vec![
        ModelEntry {
            name: "base".to_string(),
            backend: SyntheticBackend::new(vocab, seq_len, 42).with_costs(fixed, per_slot),
            spec: None,
        },
        ModelEntry {
            name: "spec".to_string(),
            backend: SyntheticBackend::new(vocab, seq_len, 43).with_costs(fixed, per_slot),
            spec: Some(SpecDecoder::new(
                SyntheticBackend::new(vocab, seq_len, 43)
                    .with_divergence(0.15, 9)
                    .with_costs(draft_fixed, Duration::from_micros(2)),
                4,
            )),
        },
    ])
    .expect("registry");

    println!("serve spec registry: {n_clients} clients x {reqs} reqs x {max_tokens} tokens");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr");
    let opts = ServeOptions {
        max_batch: 4,
        queue_depth: 256,
        max_tokens_cap: 64,
        models: registry.names(),
        ..ServeOptions::default()
    };
    let t0 = Instant::now();
    let sched = std::thread::scope(|s| {
        for id in 0..n_clients {
            s.spawn(move || registry_client(addr, id, reqs, max_tokens, vocab));
        }
        serve_on(&registry, listener, Some(n_clients), opts).expect("serve")
    });
    let wall = t0.elapsed().as_secs_f64();
    let tok_s = (n_clients * reqs * max_tokens) as f64 / wall;
    let spec = sched.spec;
    println!(
        "  {tok_s:>8.0} tok/s  accept {:.0}%  ({} drafted, {} verify passes)",
        spec.accept_rate() * 100.0,
        spec.drafted,
        spec.verify_passes
    );
    let queues: Vec<Json> = sched
        .model_queues
        .iter()
        .map(|q| {
            Json::obj(vec![
                ("model", Json::str(q.name.as_str())),
                ("admitted", Json::num(q.admitted as f64)),
                ("completed", Json::num(q.completed as f64)),
                ("peak_depth", Json::num(q.peak_depth as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("n_clients", Json::num(n_clients as f64)),
                ("reqs_per_client", Json::num(reqs as f64)),
                ("max_tokens", Json::num(max_tokens as f64)),
                ("models", Json::num(2.0)),
                ("spec_k", Json::num(4.0)),
                ("draft_fixed_cost_us", Json::num(draft_fixed.as_micros() as f64)),
            ]),
        ),
        ("tokens_per_s", Json::Num(tok_s)),
        ("completed", Json::num(sched.completed as f64)),
        ("drafted", Json::num(spec.drafted as f64)),
        ("accepted", Json::num(spec.accepted as f64)),
        ("accept_rate", Json::Num(spec.accept_rate())),
        ("verify_passes", Json::num(spec.verify_passes as f64)),
        ("rounds", Json::num(spec.rounds as f64)),
        ("model_queues", Json::Arr(queues)),
    ])
}

/// One overload client: pipelines its whole burst up front (no
/// ping-pong self-throttling), then drains the replies. Returns the
/// server-measured latencies of the accepted requests plus how many
/// were shed with a structured `overloaded` rejection.
fn overload_client(
    addr: SocketAddr,
    id: usize,
    reqs: usize,
    max_tokens: usize,
    vocab: usize,
) -> (Vec<f64>, usize) {
    let mut client =
        Client::connect_timeout(addr, Duration::from_secs(120)).expect("connect");
    for i in 0..reqs {
        let prompt: Vec<i32> =
            (0..4).map(|j| ((id * 31 + i * 7 + j) % vocab) as i32).collect();
        client.send(&ClientRequest::tokens(prompt).max_tokens(max_tokens)).expect("send");
    }
    let mut latencies = Vec::new();
    let mut shed = 0usize;
    for _ in 0..reqs {
        match client.read_reply().expect("transport") {
            Ok(reply) => latencies.push(reply.latency_ms),
            Err(e) => {
                assert_eq!(e.code, "overloaded", "unexpected rejection: {e:?}");
                assert!(e.retry_after_ms.is_some(), "shed without retry hint: {e:?}");
                shed += 1;
            }
        }
    }
    (latencies, shed)
}

/// Overload scenario: every client pipelines a burst, so the offered
/// load is several times the backend's capacity from the first
/// millisecond. Without admission control every request is accepted and
/// the tail's queue wait balloons the accepted p99; with
/// `max_queue_wait_ms` set the stale tail sheds (structured
/// `overloaded` + retry hint) and the accepted p99 stays near the
/// bound. Asserts the bounded run sheds and improves accepted p99 ≥2x
/// (tolerant-mode: note). Returns the `overload` section of
/// `BENCH_serve.json`.
fn bench_serve_overload() -> Json {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let tolerant = std::env::var("FAAR_BENCH_TOLERANT").is_ok();
    let (n_clients, reqs, max_tokens) =
        if fast { (4usize, 6usize, 8usize) } else { (8, 10, 8) };
    let (vocab, seq_len) = (512, 64);
    let fixed = Duration::from_millis(2);
    let per_slot = Duration::from_micros(20);
    let max_batch = 2usize;
    let wait_bound_ms = 120u64;

    println!(
        "serve overload: {n_clients} clients burst {reqs} reqs x {max_tokens} tokens \
         against a {}ms-step batch-{max_batch} backend",
        fixed.as_millis()
    );
    let mut runs = vec![];
    let mut p99s = [0.0f64; 2];
    for (mode, wait_ms) in [(0usize, 0u64), (1, wait_bound_ms)] {
        let backend =
            SyntheticBackend::new(vocab, seq_len, 42).with_costs(fixed, per_slot);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let opts = ServeOptions {
            max_batch,
            queue_depth: 1024,
            max_tokens_cap: 64,
            max_queue_wait_ms: wait_ms,
            ..ServeOptions::default()
        };
        let t0 = Instant::now();
        let (latencies, shed_client, sched) = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_clients)
                .map(|id| s.spawn(move || overload_client(addr, id, reqs, max_tokens, vocab)))
                .collect();
            let sched = serve_on(&backend, listener, Some(n_clients), opts).expect("serve");
            let (mut latencies, mut shed) = (vec![], 0usize);
            for h in handles {
                let (lat, sh) = h.join().expect("client panicked");
                latencies.extend(lat);
                shed += sh;
            }
            (latencies, shed, sched)
        });
        let wall = t0.elapsed().as_secs_f64();
        let offered = n_clients * reqs;
        let accepted = latencies.len();
        let shed_rate = shed_client as f64 / offered as f64;
        let tok_s = (accepted * max_tokens) as f64 / wall;
        let (p50, p99) =
            (stats::percentile(&latencies, 50.0), stats::percentile(&latencies, 99.0));
        p99s[mode] = p99;
        println!(
            "  wait bound {wait_ms:>3}ms: {accepted:>3}/{offered} accepted \
             ({:.0}% shed)  p50 {p50:>7.2} ms  p99 {p99:>7.2} ms  {tok_s:>6.0} tok/s",
            shed_rate * 100.0
        );
        assert_eq!(
            sched.shed as usize, shed_client,
            "server-side shed count must match the structured rejections clients saw"
        );
        runs.push(Json::obj(vec![
            ("max_queue_wait_ms", Json::num(wait_ms as f64)),
            ("offered", Json::num(offered as f64)),
            ("completed", Json::num(sched.completed as f64)),
            ("shed", Json::num(sched.shed as f64)),
            ("shed_rate", Json::Num(shed_rate)),
            ("accepted_p50_ms", Json::Num(p50)),
            ("accepted_p99_ms", Json::Num(p99)),
            ("accepted_tokens_per_s", Json::Num(tok_s)),
            ("wall_s", Json::Num(wall)),
        ]));
    }
    let improvement = p99s[0] / p99s[1].max(1e-12);
    println!("  load-shedding accepted-p99 improvement: {improvement:.1}x");
    if !fast && improvement < 2.0 {
        let msg = format!(
            "load shedding improved accepted p99 only {improvement:.2}x (floor 2x)"
        );
        if tolerant {
            println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
        } else {
            panic!("{msg}");
        }
    }
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("n_clients", Json::num(n_clients as f64)),
                ("reqs_per_client", Json::num(reqs as f64)),
                ("max_tokens", Json::num(max_tokens as f64)),
                ("fixed_cost_us", Json::num(fixed.as_micros() as f64)),
                ("per_slot_cost_us", Json::num(per_slot.as_micros() as f64)),
                ("max_batch", Json::num(max_batch as f64)),
                ("queue_wait_bound_ms", Json::num(wait_bound_ms as f64)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
        ("p99_improvement", Json::Num(improvement)),
    ])
}

/// One streaming decode client for the mixed-load bench: returns the
/// inter-frame gaps (ms) between consecutive stream frames — the first
/// frame is time-to-first-token, not an inter-token gap, so it is
/// dropped.
fn mixed_decoder(addr: SocketAddr, id: usize, tokens: usize, vocab: usize) -> Vec<f64> {
    let mut client =
        Client::connect_timeout(addr, Duration::from_secs(120)).expect("connect");
    let prompt: Vec<i32> = (0..4).map(|j| ((id * 31 + j * 7) % vocab) as i32).collect();
    let req = ClientRequest::tokens(prompt).max_tokens(tokens);
    let mut gaps = Vec::with_capacity(tokens);
    let mut last: Option<Instant> = None;
    let reply = client
        .request_stream_with(&req, |_frame| {
            let now = Instant::now();
            if let Some(prev) = last {
                gaps.push(now.duration_since(prev).as_secs_f64() * 1e3);
            }
            last = Some(now);
        })
        .expect("transport");
    reply.expect("server error");
    gaps
}

/// Mixed-load scenario: streaming decode clients are mid-generation when
/// one long prompt arrives. Without chunked prefill the monolithic
/// prefill of the newcomer stalls every decoder for its full duration;
/// with a per-step token budget the stall is amortized across steps.
/// Runs the same load with `prefill_chunk_tokens` 0 and 64 and asserts
/// the chunked p99 inter-token gap is ≥2x better (tolerant-mode: note).
/// Returns the `mixed` section of `BENCH_serve.json`.
fn bench_serve_mixed() -> Json {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let tolerant = std::env::var("FAAR_BENCH_TOLERANT").is_ok();
    let (decoders, decode_tokens, long_prompt, arrive_ms) =
        if fast { (4usize, 48usize, 1024usize, 8u64) } else { (8, 96, 4096, 20) };
    let (vocab, seq_len) = (512, 8192);
    let fixed = Duration::from_micros(250);
    let per_slot = Duration::from_micros(15);
    let per_prefill_token = Duration::from_micros(20);
    let chunk = 64usize;

    println!(
        "serve mixed load: {decoders} decoders x {decode_tokens} tokens + one \
         {long_prompt}-token prompt at t+{arrive_ms}ms"
    );
    let mut runs = vec![];
    let mut p99s = [0.0f64; 2];
    for (mode, chunk_tokens) in [(0usize, 0usize), (1, chunk)] {
        let backend = SyntheticBackend::new(vocab, seq_len, 42)
            .with_costs(fixed, per_slot)
            .with_prefill_cost(per_prefill_token);
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr");
        let opts = ServeOptions {
            max_batch: decoders + 1,
            queue_depth: 64,
            max_tokens_cap: decode_tokens,
            prefill_chunk_tokens: chunk_tokens,
            ..ServeOptions::default()
        };
        let (gaps, sched) = std::thread::scope(|s| {
            let handles: Vec<_> = (0..decoders)
                .map(|id| s.spawn(move || mixed_decoder(addr, id, decode_tokens, vocab)))
                .collect();
            // the long prompt arrives once the decoders are mid-stream
            let long = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(arrive_ms));
                let mut client =
                    Client::connect_timeout(addr, Duration::from_secs(120)).expect("connect");
                let prompt: Vec<i32> =
                    (0..long_prompt).map(|i| (i % vocab) as i32).collect();
                let req = ClientRequest::tokens(prompt).max_tokens(8);
                client.request(&req).expect("transport").expect("server error");
            });
            let sched =
                serve_on(&backend, listener, Some(decoders + 1), opts).expect("serve");
            long.join().expect("long client panicked");
            let mut gaps = vec![];
            for h in handles {
                gaps.extend(h.join().expect("decoder panicked"));
            }
            (gaps, sched)
        });
        let (p50, p99) =
            (stats::percentile(&gaps, 50.0), stats::percentile(&gaps, 99.0));
        p99s[mode] = p99;
        println!(
            "  chunk {chunk_tokens:>2}: inter-token p50 {p50:>7.2} ms  p99 {p99:>7.2} ms  \
             ({} prefill chunks, {:.0}% budget used)",
            sched.prefill_chunks,
            sched.budget_utilization() * 100.0
        );
        runs.push(Json::obj(vec![
            ("prefill_chunk_tokens", Json::num(chunk_tokens as f64)),
            ("inter_token_p50_ms", Json::Num(p50)),
            ("inter_token_p99_ms", Json::Num(p99)),
            ("steps", Json::num(sched.steps as f64)),
            ("prefill_chunks", Json::num(sched.prefill_chunks as f64)),
            ("prefill_tokens", Json::num(sched.prefill_tokens as f64)),
            ("budget_utilization", Json::Num(sched.budget_utilization())),
            ("prefix_hit_rate", Json::Num(sched.prefix_hit_rate())),
            ("kv_pages_hwm", Json::num(sched.cache.kv_pages_hwm as f64)),
            ("completed", Json::num(sched.completed as f64)),
        ]));
    }
    let improvement = p99s[0] / p99s[1].max(1e-12);
    println!("  chunked-prefill p99 improvement: {improvement:.1}x");
    if !fast && improvement < 2.0 {
        let msg = format!(
            "chunked prefill improved p99 inter-token latency only {improvement:.2}x \
             (floor 2x)"
        );
        if tolerant {
            println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
        } else {
            panic!("{msg}");
        }
    }
    Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("decoders", Json::num(decoders as f64)),
                ("decode_tokens", Json::num(decode_tokens as f64)),
                ("long_prompt_tokens", Json::num(long_prompt as f64)),
                ("arrive_ms", Json::num(arrive_ms as f64)),
                ("per_prefill_token_us", Json::num(per_prefill_token.as_micros() as f64)),
                ("fixed_cost_us", Json::num(fixed.as_micros() as f64)),
                ("per_slot_cost_us", Json::num(per_slot.as_micros() as f64)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
        ("p99_improvement", Json::Num(improvement)),
    ])
}

/// Decode `new_tokens` continuations for `batch` slots through the
/// native backend; returns (wall seconds, generated tokens).
fn native_decode_run(
    backend: &NativeBackend,
    batch: usize,
    prompt_len: usize,
    new_tokens: usize,
) -> (f64, usize) {
    let seq_len = backend.seq_len();
    let mut slots: Vec<DecodeSlot> = (0..batch)
        .map(|b| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|i| ((b * 131 + i * 7) % 256) as i32).collect();
            DecodeSlot::new(&prompt, new_tokens, seq_len).expect("slot")
        })
        .collect();
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done()) {
        decode_step(backend, &mut slots).expect("decode step");
    }
    let wall = t0.elapsed().as_secs_f64();
    for slot in &slots {
        backend.release(slot);
    }
    assert_eq!(backend.kv_outstanding(), 0, "bench leaked KV pages");
    (wall, batch * new_tokens)
}

/// Native-backend decode throughput: tokens/sec at batch 1/4/16, KV
/// cache on vs off, on a seq_len-256 model so the window reaches the
/// T >= 256 regime where the O(T) cached step must beat the O(T²)
/// recompute by >= 2x. Runs everywhere — pure rust, no artifacts.
fn bench_native() {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    // a loaded/shared runner can squash wall-clock ratios without the
    // code being wrong — FAAR_BENCH_TOLERANT downgrades the speedup
    // floor to a printed note instead of a suite failure
    let tolerant = std::env::var("FAAR_BENCH_TOLERANT").is_ok();
    // full mode fills the 256-token window exactly (224 prompt + 32 new)
    let (prompt_len, new_tokens) = if fast { (56, 8) } else { (224, 32) };
    let cfg = native_config("bench", 256, 64, 2, 2, 256).expect("bench config");
    let manifest = manifest_from_config(cfg);
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    println!(
        "native decode: {} layers packed ({:.2} MiB), prompt {prompt_len} + {new_tokens} new tokens",
        model.n_packed(),
        model.packed_payload_bytes() as f64 / (1 << 20) as f64
    );

    let mut runs = vec![];
    for &batch in &[1usize, 4, 16] {
        let mut tok_s = [0.0f64; 2];
        for (slot_idx, use_cache) in [(0usize, true), (1usize, false)] {
            let backend = NativeBackend::new(
                model.clone(),
                NativeOptions { use_cache, max_pages: 2048, ..NativeOptions::default() },
            );
            let (wall, tokens) = native_decode_run(&backend, batch, prompt_len, new_tokens);
            tok_s[slot_idx] = tokens as f64 / wall;
            println!(
                "  batch {batch:>2} kv={:<5} {:>9.1} tok/s  ({:.3}s wall)",
                use_cache,
                tok_s[slot_idx],
                wall
            );
            runs.push(Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("kv_cache", Json::Bool(use_cache)),
                ("tokens_per_s", Json::Num(tok_s[slot_idx])),
                ("wall_s", Json::Num(wall)),
            ]));
        }
        let speedup = tok_s[0] / tok_s[1].max(1e-12);
        println!("  batch {batch:>2} kv-cache speedup: {speedup:.1}x");
        if !fast && speedup < 2.0 {
            let msg =
                format!("KV cache speedup {speedup:.2}x below the 2x floor at batch {batch}");
            if tolerant {
                println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
            } else {
                panic!("{msg}");
            }
        }
    }
    let doc = Json::obj(vec![
        ("group", Json::str("native")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str("bench")),
                ("vocab", Json::num(256.0)),
                ("d_model", Json::num(64.0)),
                ("n_layers", Json::num(2.0)),
                ("seq_len", Json::num(256.0)),
                ("prompt_len", Json::num(prompt_len as f64)),
                ("new_tokens", Json::num(new_tokens as f64)),
                ("format", Json::str("nvfp4")),
                ("act_quant", Json::Bool(true)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write("BENCH_native.json", format!("{}\n", doc.to_string_pretty())) {
        Ok(()) => println!("→ wrote BENCH_native.json"),
        Err(e) => eprintln!("[warn] could not write BENCH_native.json: {e}"),
    }
}

fn main() {
    // the serving load bench and the native decode bench run everywhere
    // (no artifacts or PJRT needed)
    let load = bench_serve_load();
    let mixed = bench_serve_mixed();
    let spec = bench_serve_spec();
    let overload = bench_serve_overload();
    let doc = Json::obj(vec![
        ("group", Json::str("serve")),
        ("load", load),
        ("mixed", mixed),
        ("spec", spec),
        ("overload", overload),
    ]);
    match std::fs::write("BENCH_serve.json", format!("{}\n", doc.to_string_pretty())) {
        Ok(()) => println!("→ wrote BENCH_serve.json"),
        Err(e) => eprintln!("[warn] could not write BENCH_serve.json: {e}"),
    }
    bench_native();

    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping bench_runtime artifact benches: run `make artifacts` first");
        return;
    }
    let mut b = Bench::new("runtime");
    let rt = Runtime::load(Path::new("artifacts"), "nano").unwrap();
    let cfg = rt.config().clone();
    let params = ParamStore::init(&rt.manifest, 42);

    b.bench("compile_lm_fwd_cold", || {
        // cold compile: fresh runtime each iteration (compile cache is
        // per-Runtime)
        let rt2 = Runtime::load(Path::new("artifacts"), "nano").unwrap();
        black_box(rt2.executable("lm_fwd").unwrap());
    });

    // eval forward: latency + throughput
    let mut rng = Rng::new(1);
    let toks: Vec<i32> =
        (0..cfg.eval_batch * (cfg.seq_len + 1)).map(|_| rng.below(cfg.vocab) as i32).collect();
    let tokens = Value::I32(toks, vec![cfg.eval_batch, cfg.seq_len + 1]);
    let mut args = params.values();
    args.push(tokens);
    rt.warmup(&["lm_fwd", "lm_fwd_aq"]).unwrap();
    let n_tok = (cfg.eval_batch * cfg.seq_len) as u64;
    b.bench_n("lm_fwd_exec", n_tok, || {
        black_box(rt.exec("lm_fwd", &args).unwrap());
    });
    b.bench_n("lm_fwd_aq_exec", n_tok, || {
        black_box(rt.exec("lm_fwd_aq", &args).unwrap());
    });

    // stage-1 step (the FAAR hot loop)
    let d = cfg.d_model;
    let name = format!("stage1_step_{d}x{d}");
    let mut w = Tensor::zeros(&[d, d]);
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    let p = nvfp4_faar::formats::nvfp4::prepare(&w);
    let mut x = Tensor::zeros(&[cfg.stage1_rows, d]);
    rng.fill_normal(&mut x.data, 0.0, 1.0);
    let s1_args = vec![
        Value::F32(x),
        Value::F32(w),
        Value::F32(p.lower.clone()),
        Value::F32(p.upper.clone()),
        Value::F32(p.scale.clone()),
        Value::F32(p.v_init.clone()),
        Value::F32(Tensor::zeros(&[d, d])),
        Value::F32(Tensor::zeros(&[d, d])),
        Value::scalar_f32(1.0),
        Value::scalar_f32(10.0),
        Value::scalar_f32(1e-2),
        Value::scalar_f32(1e-2),
    ];
    rt.warmup(&[&name]).unwrap();
    b.bench(&format!("{name}_exec"), || {
        black_box(rt.exec(&name, &s1_args).unwrap());
    });

    // kernel: pallas interpret vs jnp lowering, same math
    let kargs = vec![
        s1_args[1].clone(),
        Value::F32(p.lower),
        Value::F32(p.upper),
        Value::F32(p.scale),
        Value::F32(p.v_init),
        Value::scalar_f32(10.0),
    ];
    rt.warmup(&["kernel_softquant", "kernel_softquant_jnp"]).unwrap();
    b.bench(&format!("kernel_softquant_pallas_{d}x{d}"), || {
        black_box(rt.exec("kernel_softquant", &kargs).unwrap());
    });
    b.bench(&format!("kernel_softquant_jnp_{d}x{d}"), || {
        black_box(rt.exec("kernel_softquant_jnp", &kargs).unwrap());
    });

    b.finish();
}
