//! End-to-end pipeline benches on the nano preset: wall-clock of each
//! quantization method (the paper's "4 GPU hours" cost claim, scaled),
//! plus serve-path generation latency. Needs `make artifacts`.

#![allow(clippy::field_reassign_with_default)]

use std::path::Path;

use nvfp4_faar::config::PipelineConfig;
use nvfp4_faar::pipeline::{Method, Workbench};
use nvfp4_faar::serve::Generator;
use nvfp4_faar::util::bench::{black_box, Bench};

fn main() {
    if !Path::new("artifacts/nano/manifest.json").exists() {
        eprintln!("skipping bench_pipeline: run `make artifacts` first");
        return;
    }
    let mut cfg = PipelineConfig::default();
    cfg.model = "nano".into();
    cfg.pretrain_steps = 200;
    cfg.stage1_steps = 30;
    cfg.stage2_steps = 20;
    cfg.eval_batches = 2;

    let mut b = Bench::new("pipeline");
    b.samples = 3;
    b.target_time = 0.0; // one run per sample: these are seconds-long

    let wb = Workbench::open(cfg).unwrap();

    for method in [
        Method::Rtn,
        Method::FourSix,
        Method::StrongBaseline,
        Method::Gptq,
        Method::MrGptq,
        Method::Faar,
        Method::Faar2fa,
    ] {
        b.bench(&format!("quantize_{}", method.name()), || {
            black_box(wb.quantize(method).unwrap());
        });
    }

    // eval + serve paths
    let outcome = wb.quantize(Method::Rtn).unwrap();
    b.bench("eval_ppl_2_batches", || {
        black_box(wb.ppl(&outcome, "wiki").unwrap());
    });

    let gen = Generator::new(&wb.rt, outcome.params.clone());
    b.bench_n("generate_16_tokens", 16, || {
        black_box(gen.generate(&[1, 2, 3, 4], 16).unwrap());
    });

    b.finish();
}
