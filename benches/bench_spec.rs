//! Speculative-decoding bench: B=1 decode throughput of draft-verify
//! (`spec_generate`) vs plain greedy decode over the synthetic cost
//! model — an expensive target step, a ~10x cheaper draft step, and a
//! multi-row verify that costs one target step regardless of `k`. The
//! emitted streams must be bit-identical (asserted every run), and the
//! best speculation depth must clear a 1.3x throughput floor
//! (`FAAR_BENCH_TOLERANT` downgrades the floor to a printed note on
//! loaded runners). Writes `BENCH_spec.json`.

use std::time::{Duration, Instant};

use nvfp4_faar::serve::{
    generate_greedy, spec_generate, GenParams, SpecDecoder, SpecStats, SyntheticBackend,
};
use nvfp4_faar::util::json::Json;

const VOCAB: usize = 512;
const SEQ_LEN: usize = 256;

fn prompt(i: usize) -> Vec<i32> {
    (0..4).map(|j| ((i * 31 + j * 7) % VOCAB) as i32).collect()
}

fn main() {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let tolerant = std::env::var("FAAR_BENCH_TOLERANT").is_ok();
    let (prompts, tokens) = if fast { (4usize, 32usize) } else { (8, 96) };
    // the accelerator-shaped economics that make speculation pay: a
    // target step dominated by fixed launch cost, a draft an order of
    // magnitude cheaper, and a multi-row verify costing ONE target step
    let target_cost = Duration::from_micros(400);
    let draft_cost = Duration::from_micros(40);
    let per_slot = Duration::from_micros(10);
    let divergence = 0.15f32;

    let target = SyntheticBackend::new(VOCAB, SEQ_LEN, 42).with_costs(target_cost, per_slot);

    println!("spec decode bench: {prompts} prompts x {tokens} tokens, B=1");
    let t0 = Instant::now();
    let mut expect = Vec::with_capacity(prompts);
    for i in 0..prompts {
        expect.push(generate_greedy(&target, &prompt(i), tokens).expect("plain decode"));
    }
    let plain_wall = t0.elapsed().as_secs_f64();
    let plain_tok_s = (prompts * tokens) as f64 / plain_wall;
    println!("  plain     {plain_tok_s:>8.0} tok/s  ({plain_wall:.3}s wall)");

    let mut runs = vec![Json::obj(vec![
        ("mode", Json::str("plain")),
        ("tokens_per_s", Json::Num(plain_tok_s)),
        ("wall_s", Json::Num(plain_wall)),
    ])];
    let mut best = (0usize, 0.0f64);
    for &k in &[2usize, 4, 8] {
        // the draft shares the target's seed but diverges on a fraction
        // of positions, so acceptance is high without being total
        let draft = SyntheticBackend::new(VOCAB, SEQ_LEN, 42)
            .with_divergence(divergence, 9)
            .with_costs(draft_cost, Duration::from_micros(2));
        let spec = SpecDecoder::new(draft, k);
        let mut stats = SpecStats::default();
        let t0 = Instant::now();
        for (i, want) in expect.iter().enumerate() {
            let (got, s) =
                spec_generate(&target, &spec, &prompt(i), tokens, GenParams::default())
                    .expect("spec decode");
            assert_eq!(&got, want, "speculative decode diverged from plain at k={k}");
            stats.add(&s);
        }
        let wall = t0.elapsed().as_secs_f64();
        let tok_s = (prompts * tokens) as f64 / wall;
        let speedup = tok_s / plain_tok_s.max(1e-12);
        if speedup > best.1 {
            best = (k, speedup);
        }
        println!(
            "  spec k={k}  {tok_s:>8.0} tok/s  ({wall:.3}s wall)  \
             {:.0}% accepted  {speedup:.2}x",
            stats.accept_rate() * 100.0
        );
        runs.push(Json::obj(vec![
            ("mode", Json::str("spec")),
            ("k", Json::num(k as f64)),
            ("tokens_per_s", Json::Num(tok_s)),
            ("wall_s", Json::Num(wall)),
            ("speedup", Json::Num(speedup)),
            ("drafted", Json::num(stats.drafted as f64)),
            ("accepted", Json::num(stats.accepted as f64)),
            ("accept_rate", Json::Num(stats.accept_rate())),
            ("verify_passes", Json::num(stats.verify_passes as f64)),
            ("rounds", Json::num(stats.rounds as f64)),
        ]));
    }
    let (best_k, best_speedup) = best;
    println!("  best: k={best_k} at {best_speedup:.2}x over plain decode");
    if !fast && best_speedup < 1.3 {
        let msg = format!(
            "speculative decode best speedup {best_speedup:.2}x (k={best_k}) \
             below the 1.3x floor"
        );
        if tolerant {
            println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
        } else {
            panic!("{msg}");
        }
    }

    let doc = Json::obj(vec![
        ("group", Json::str("spec")),
        (
            "config",
            Json::obj(vec![
                ("vocab", Json::num(VOCAB as f64)),
                ("seq_len", Json::num(SEQ_LEN as f64)),
                ("prompts", Json::num(prompts as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("target_cost_us", Json::num(target_cost.as_micros() as f64)),
                ("draft_cost_us", Json::num(draft_cost.as_micros() as f64)),
                ("per_slot_cost_us", Json::num(per_slot.as_micros() as f64)),
                ("divergence", Json::Num(divergence as f64)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
        ("best_k", Json::num(best_k as f64)),
        ("best_speedup", Json::Num(best_speedup)),
    ]);
    match std::fs::write("BENCH_spec.json", format!("{}\n", doc.to_string_pretty())) {
        Ok(()) => println!("→ wrote BENCH_spec.json"),
        Err(e) => eprintln!("[warn] could not write BENCH_spec.json: {e}"),
    }
}
