//! Multi-row fused GEMM benches → `BENCH_kernels.json`.
//!
//! Measures the two call sites of `Linear::matmul` (DESIGN.md §11)
//! against the token-by-token path they replace, on a pure-rust model —
//! no artifacts, no PJRT:
//!
//! * **prefill** — `NativeModel::prefill` (all prompt positions through
//!   the seven packed linears in `[T, ·]` form, one payload decode per
//!   row tile) vs `logits_window` (T full passes over the payload), at
//!   T ∈ {16, 64, 256}. Records tokens/s and the effective packed-GB/s
//!   the naive path would have had to stream, and **asserts** the ≥ 3x
//!   speedup floor at T = 256.
//! * **decode** — cross-slot batched decode through `NativeBackend` at
//!   B ∈ {1, 4, 16} (one `[B, ·]` pass per packed layer per step), on
//!   both KV formats (`f32` and `e4m3`), with effective packed-GB/s
//!   alongside tokens/s.
//!
//! The `config` block records the dispatched kernel path (avx2 / neon /
//! scalar) and the detected CPU features, so every number in the perf
//! trajectory is attributable to a code path. With a SIMD path live,
//! the T = 256 prefill additionally **asserts** ≥ 2x the committed
//! 1.87 eff GB/s scalar baseline (DESIGN.md §12).
//!
//! Knobs: `FAAR_BENCH_FAST` shrinks the sweep (and skips the
//! assertions); `FAAR_BENCH_TOLERANT` keeps the full sweep but
//! downgrades the assertions to printed notes — for loaded CI runners
//! where wall-clock ratios are noisy. `FAAR_FORCE_SCALAR=1` pins the
//! scalar kernels (and skips the SIMD floor).

use std::time::Instant;

use nvfp4_faar::formats::codec::FormatKind;
use nvfp4_faar::infer::kernels::{cpu_features, kernel_path, KernelPath};
use nvfp4_faar::infer::preset::{manifest_from_config, native_config};
use nvfp4_faar::infer::{quantize_store, KvFormat, NativeBackend, NativeModel, NativeOptions};
use nvfp4_faar::serve::batch::{decode_step, DecodeSlot, StepBackend};
use nvfp4_faar::train::ParamStore;
use nvfp4_faar::util::bench::black_box;
use nvfp4_faar::util::json::Json;

/// The committed scalar-kernel prefill bandwidth at T = 256 (eff GB/s,
/// BENCH_kernels.json as of PR 5) — the reference the SIMD floor below
/// is measured against.
const SCALAR_BASELINE_GBPS: f64 = 1.87;

/// Best-of-`iters` wall seconds for `f`.
fn time_best<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn build_model() -> (NativeModel, usize) {
    // d_model 128 so the seven linears dominate the forward (the regime
    // the multi-row kernel targets); seq_len 256 for the T = 256 point
    let cfg = native_config("kernels", 256, 128, 2, 2, 256).expect("bench config");
    let manifest = manifest_from_config(cfg);
    let fp = ParamStore::init(&manifest, 42);
    let store = quantize_store(&manifest, &fp, FormatKind::Nvfp4).expect("quantize");
    let model = NativeModel::new(&manifest.config, &store, true).expect("model");
    let payload = model.packed_payload_bytes();
    (model, payload)
}

fn bench_prefill(model: &NativeModel, payload: usize, fast: bool, tolerant: bool) -> Vec<Json> {
    let sizes: &[usize] = if fast { &[16, 64] } else { &[16, 64, 256] };
    let mut runs = vec![];
    for &t in sizes {
        let prompt: Vec<i32> = (0..t).map(|i| ((i * 7 + 3) % 256) as i32).collect();
        // parity first — a bench over diverging paths measures nothing
        let reference = model.logits_window(&prompt).expect("reference");
        assert_eq!(model.prefill(&prompt).expect("prefill"), reference, "prefill diverged");

        let iters = if t >= 256 { 3 } else { 5 };
        let wall_seq = time_best(iters, || {
            black_box(model.logits_window(&prompt).expect("seq"));
        });
        let wall_pre = time_best(iters, || {
            black_box(model.prefill(&prompt).expect("prefill"));
        });
        // single-thread kernel view: same comparison with the column
        // parallelism pinned to 1 worker on both sides
        let wall_pre_1t = time_best(iters, || {
            black_box(model.prefill_paged(&prompt, 16, KvFormat::F32, 1).expect("prefill 1t"));
        });
        let speedup = wall_seq / wall_pre.max(1e-12);
        let speedup_1t = wall_seq / wall_pre_1t.max(1e-12);
        // effective bandwidth: the packed bytes the token-by-token path
        // streams for this window (payload × T), over each wall clock
        let naive_bytes = (payload * t) as f64;
        println!(
            "  prefill T={t:>3}: seq {:>8.1} tok/s  prefill {:>8.1} tok/s  \
             ({speedup:.2}x, 1t {speedup_1t:.2}x, {:.2} -> {:.2} eff GB/s)",
            t as f64 / wall_seq,
            t as f64 / wall_pre,
            naive_bytes / wall_seq / 1e9,
            naive_bytes / wall_pre / 1e9,
        );
        if t == 256 {
            let msg = format!("prefill speedup {speedup:.2}x below the 3x floor at T=256");
            if tolerant && speedup < 3.0 {
                println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
            } else {
                assert!(speedup >= 3.0, "{msg}");
            }
            // with a vector path dispatched, bandwidth must clear 2x the
            // committed scalar baseline (the PR-6 acceptance floor)
            if kernel_path() != KernelPath::Scalar {
                let eff = naive_bytes / wall_pre / 1e9;
                let floor = 2.0 * SCALAR_BASELINE_GBPS;
                let msg = format!(
                    "prefill {eff:.2} eff GB/s below the {floor:.2} GB/s SIMD floor \
                     (2x the {SCALAR_BASELINE_GBPS} GB/s scalar baseline) at T=256"
                );
                if tolerant && eff < floor {
                    println!("  [note] {msg} — tolerated (FAAR_BENCH_TOLERANT)");
                } else {
                    assert!(eff >= floor, "{msg}");
                }
            }
        }
        runs.push(Json::obj(vec![
            ("t", Json::num(t as f64)),
            ("seq_tokens_per_s", Json::Num(t as f64 / wall_seq)),
            ("prefill_tokens_per_s", Json::Num(t as f64 / wall_pre)),
            ("prefill_1t_tokens_per_s", Json::Num(t as f64 / wall_pre_1t)),
            ("seq_eff_gbps", Json::Num(naive_bytes / wall_seq / 1e9)),
            ("prefill_eff_gbps", Json::Num(naive_bytes / wall_pre / 1e9)),
            ("speedup", Json::Num(speedup)),
            ("speedup_1t", Json::Num(speedup_1t)),
        ]));
    }
    runs
}

fn decode_run(backend: &NativeBackend, batch: usize, prompt_len: usize, new_tokens: usize) -> f64 {
    let seq_len = backend.seq_len();
    let mut slots: Vec<DecodeSlot> = (0..batch)
        .map(|b| {
            let prompt: Vec<i32> =
                (0..prompt_len).map(|i| ((b * 131 + i * 7) % 256) as i32).collect();
            DecodeSlot::new(&prompt, new_tokens, seq_len).expect("slot")
        })
        .collect();
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done()) {
        decode_step(backend, &mut slots).expect("decode step");
    }
    let wall = t0.elapsed().as_secs_f64();
    for slot in &slots {
        backend.release(slot);
    }
    assert_eq!(backend.kv_outstanding(), 0, "bench leaked KV pages");
    (batch * new_tokens) as f64 / wall
}

fn bench_decode(model: &NativeModel, payload: usize, fast: bool) -> Vec<Json> {
    let (prompt_len, new_tokens) = if fast { (16, 8) } else { (32, 32) };
    let mut runs = vec![];
    for &batch in &[1usize, 4, 16] {
        for kv_format in [KvFormat::F32, KvFormat::E4m3] {
            let backend = NativeBackend::new(
                model.clone(),
                NativeOptions { max_pages: 4096, kv_format, ..NativeOptions::default() },
            );
            // warm the caches/scratch once, then measure
            decode_run(&backend, batch, prompt_len, 2);
            let tok_s = decode_run(&backend, batch, prompt_len, new_tokens);
            // same naive-stream convention as prefill: the packed bytes a
            // per-token payload sweep would read for these tokens
            let eff_gbps = payload as f64 * tok_s / 1e9;
            println!(
                "  decode B={batch:>2} kv={:<4}: {tok_s:>9.1} tok/s  \
                 ({eff_gbps:.2} eff GB/s, cross-slot batched, kv on)",
                kv_format.name()
            );
            runs.push(Json::obj(vec![
                ("batch", Json::num(batch as f64)),
                ("kv_format", Json::str(kv_format.name())),
                ("tokens_per_s", Json::Num(tok_s)),
                ("eff_gbps", Json::Num(eff_gbps)),
            ]));
        }
    }
    runs
}

fn main() {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let tolerant = std::env::var("FAAR_BENCH_TOLERANT").is_ok() || fast;
    let (model, payload) = build_model();
    println!(
        "multi-row fused GEMM: {} packed layers, {:.2} MiB payload, {} kernels [{}]{}",
        model.n_packed(),
        payload as f64 / (1 << 20) as f64,
        kernel_path().name(),
        cpu_features(),
        if fast { " (fast mode)" } else { "" }
    );
    let prefill_runs = bench_prefill(&model, payload, fast, tolerant);
    let decode_runs = bench_decode(&model, payload, fast);
    let doc = Json::obj(vec![
        ("group", Json::str("kernels")),
        (
            "config",
            Json::obj(vec![
                ("model", Json::str("kernels")),
                ("vocab", Json::num(256.0)),
                ("d_model", Json::num(128.0)),
                ("n_layers", Json::num(2.0)),
                ("seq_len", Json::num(256.0)),
                ("format", Json::str("nvfp4")),
                ("kernel_path", Json::str(kernel_path().name())),
                ("cpu_features", Json::str(cpu_features())),
                ("payload_bytes", Json::num(payload as f64)),
                ("fast", Json::Bool(fast)),
            ]),
        ),
        ("prefill", Json::Arr(prefill_runs)),
        ("decode", Json::Arr(decode_runs)),
    ]);
    match std::fs::write("BENCH_kernels.json", format!("{}\n", doc.to_string_pretty())) {
        Ok(()) => println!("→ wrote BENCH_kernels.json"),
        Err(e) => eprintln!("[warn] could not write BENCH_kernels.json: {e}"),
    }
}
