//! Sampler-overhead bench: the generation API v2 moved token selection
//! out of the backends into the decode core, so the sampler's per-step
//! cost is pure scheduler-thread overhead — it must stay a small
//! fraction of a realistic accelerator step. This measures decode
//! throughput through the full `decode_step` core (synthetic backend
//! with an accelerator-shaped cost model) at micro-batch 1/4/16, greedy
//! vs fully-loaded sampling (temperature + top-k + top-p + repetition
//! penalty), writes `BENCH_sampling.json`, and asserts the sampled path
//! stays within 10% of greedy throughput.

use std::time::{Duration, Instant};

use nvfp4_faar::serve::batch::{decode_step, DecodeSlot};
use nvfp4_faar::serve::{GenParams, SyntheticBackend};
use nvfp4_faar::util::json::Json;

const VOCAB: usize = 512;
const SEQ_LEN: usize = 64;

/// Decode `new_tokens` continuations for `batch` slots; returns wall
/// seconds (the per-request params vary per slot, like real traffic).
fn decode_run(
    backend: &SyntheticBackend,
    batch: usize,
    new_tokens: usize,
    params: &dyn Fn(usize) -> GenParams,
) -> f64 {
    let mut slots: Vec<DecodeSlot> = (0..batch)
        .map(|b| {
            let prompt: Vec<i32> = (0..4).map(|i| ((b * 131 + i * 7) % VOCAB) as i32).collect();
            DecodeSlot::with_params(&prompt, new_tokens, SEQ_LEN, params(b)).expect("slot")
        })
        .collect();
    let t0 = Instant::now();
    while slots.iter().any(|s| !s.done()) {
        decode_step(backend, &mut slots).expect("decode step");
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let fast = std::env::var("FAAR_BENCH_FAST").is_ok();
    let (new_tokens, repeats) = if fast { (16, 2) } else { (64, 5) };
    // accelerator-shaped step cost: a fixed launch overhead plus a small
    // per-slot compute cost. The sampler runs on top of this on the
    // scheduler thread; its overhead is measured against it.
    let fixed = Duration::from_micros(1000);
    let per_slot = Duration::from_micros(50);
    let sampled_params = |seed: usize| GenParams {
        temperature: 0.8,
        top_k: 64,
        top_p: 0.9,
        repetition_penalty: 1.1,
        seed: seed as u64,
        ..GenParams::default()
    };

    println!(
        "sampler overhead: vocab {VOCAB}, {new_tokens} tokens/slot, step cost \
         {}µs + {}µs/slot, best of {repeats}",
        fixed.as_micros(),
        per_slot.as_micros()
    );
    let mut runs = vec![];
    for &batch in &[1usize, 4, 16] {
        let backend = SyntheticBackend::new(VOCAB, SEQ_LEN, 42).with_costs(fixed, per_slot);
        let tokens = (batch * new_tokens) as f64;
        // best-of-N walls: the spin-wait cost model is accurate, so min
        // filters scheduler noise without hiding systematic overhead
        let mut greedy_wall = f64::INFINITY;
        let mut sampled_wall = f64::INFINITY;
        for _ in 0..repeats {
            greedy_wall = greedy_wall
                .min(decode_run(&backend, batch, new_tokens, &|_| GenParams::default()));
            sampled_wall =
                sampled_wall.min(decode_run(&backend, batch, new_tokens, &sampled_params));
        }
        let (greedy_tok_s, sampled_tok_s) = (tokens / greedy_wall, tokens / sampled_wall);
        let overhead_pct = (sampled_wall / greedy_wall - 1.0) * 100.0;
        println!(
            "  batch {batch:>2}: greedy {greedy_tok_s:>9.1} tok/s  sampled \
             {sampled_tok_s:>9.1} tok/s  overhead {overhead_pct:>5.2}%"
        );
        if !fast {
            assert!(
                overhead_pct < 10.0,
                "sampler overhead {overhead_pct:.2}% exceeds the 10% budget at batch {batch}"
            );
        }
        runs.push(Json::obj(vec![
            ("batch", Json::num(batch as f64)),
            ("greedy_tokens_per_s", Json::Num(greedy_tok_s)),
            ("sampled_tokens_per_s", Json::Num(sampled_tok_s)),
            ("overhead_pct", Json::Num(overhead_pct)),
        ]));
    }
    let doc = Json::obj(vec![
        ("group", Json::str("sampling")),
        (
            "config",
            Json::obj(vec![
                ("vocab", Json::num(VOCAB as f64)),
                ("seq_len", Json::num(SEQ_LEN as f64)),
                ("new_tokens", Json::num(new_tokens as f64)),
                ("fixed_cost_us", Json::num(fixed.as_micros() as f64)),
                ("per_slot_cost_us", Json::num(per_slot.as_micros() as f64)),
                ("temperature", Json::Num(0.8)),
                ("top_k", Json::num(64.0)),
                ("top_p", Json::Num(0.9)),
                ("repetition_penalty", Json::Num(1.1)),
            ]),
        ),
        ("runs", Json::Arr(runs)),
    ]);
    match std::fs::write("BENCH_sampling.json", format!("{}\n", doc.to_string_pretty())) {
        Ok(()) => println!("→ wrote BENCH_sampling.json"),
        Err(e) => eprintln!("[warn] could not write BENCH_sampling.json: {e}"),
    }
}
