//! Codec throughput: E4M3 / E2M1 / NVFP4 prepare + pack (L3 hot paths of
//! the quantization pipeline), plus the packed-`QuantTensor` scalar-vs-
//! block-parallel comparison at ≥1M elements. Results land in
//! results/bench/formats.json; the headline packed-path comparison is
//! also written as one machine-readable line to BENCH_formats.json.

use nvfp4_faar::formats::codec::{self, rtn_decisions, FormatCodec, FormatKind, Parallelism};
use nvfp4_faar::formats::nvfp4::Nvfp4;
use nvfp4_faar::formats::{e2m1, e4m3, nvfp4};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::json::Json;
use nvfp4_faar::util::rng::Rng;
use nvfp4_faar::util::threads;

fn rand_t(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 0.0, 0.05);
    t
}

fn main() {
    let mut b = Bench::new("formats");
    let n = 1 << 20;

    let xs: Vec<f32> = {
        let mut rng = Rng::new(1);
        (0..n).map(|_| rng.normal_f32(0.0, 50.0)).collect()
    };
    b.bench_n("e4m3_encode_1M", n as u64, || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(e4m3::encode(x) as u32);
        }
        black_box(acc);
    });

    let codes: Vec<u8> = (0..n).map(|i| (i % 256) as u8).collect();
    b.bench_n("e4m3_decode_1M", n as u64, || {
        let mut acc = 0.0f32;
        for &c in &codes {
            let v = e4m3::decode(c);
            if v.is_finite() {
                acc += v;
            }
        }
        black_box(acc);
    });

    b.bench_n("e2m1_encode_rtn_1M", n as u64, || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(e2m1::encode_rtn(x / 60.0) as u32);
        }
        black_box(acc);
    });

    let codes4: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
    b.bench_n("e2m1_pack_unpack_1M", n as u64, || {
        let packed = e2m1::pack(&codes4);
        black_box(e2m1::unpack(&packed, n));
    });

    // weight-tensor level (tiny wq stack: 4 x 128 x 128)
    let w = rand_t(&[4, 128, 128], 2);
    let numel = w.numel() as u64;
    b.bench_n("prepare_4x128x128", numel, || {
        black_box(nvfp4::prepare(&w));
    });

    let p = nvfp4::prepare(&w);
    b.bench_n("rtn_quant_4x128x128", numel, || {
        black_box(codec::rtn_quant(&w, &p));
    });

    let v = p.v_init.map(|x| if x >= 0.5 { 1.0 } else { 0.0 });
    b.bench_n("pack_4x128x128", numel, || {
        black_box(nvfp4::PackedTensor::pack(&w, &p, &v));
    });

    let packed = nvfp4::PackedTensor::pack(&w, &p, &v);
    b.bench_n("unpack_4x128x128", numel, || {
        black_box(packed.unpack());
    });

    // ---- packed QuantTensor: block-parallel vs scalar at 1M+ elements ----
    // (the tentpole claim: the parallel path must beat the scalar path)
    let big = rand_t(&[8, 512, 256], 7); // 1,048,576 elements
    let n_big = big.numel() as u64;
    let nv = Nvfp4;
    let p_big = FormatCodec::prepare(&nv, &big);
    let v_big = rtn_decisions(&p_big);
    let workers = threads::default_workers();

    let enc_s = b.bench_n("qt_encode_scalar_1M", n_big, || {
        black_box(nv.encode_mode(&big, &p_big, &v_big, Parallelism::Scalar));
    });
    let enc_p = b.bench_n("qt_encode_parallel_1M", n_big, || {
        black_box(nv.encode_mode(&big, &p_big, &v_big, Parallelism::Workers(workers)));
    });
    let qt = nv.encode_mode(&big, &p_big, &v_big, Parallelism::Auto);
    let dec_s = b.bench_n("qt_decode_scalar_1M", n_big, || {
        black_box(nv.decode_mode(&qt, Parallelism::Scalar).unwrap());
    });
    let dec_p = b.bench_n("qt_decode_parallel_1M", n_big, || {
        black_box(nv.decode_mode(&qt, Parallelism::Workers(workers)).unwrap());
    });

    // packed-vs-dequantized memory + headline throughput line
    let packed_bytes = qt.payload_bytes();
    let dense_bytes = qt.numel() * 4;
    let enc_speedup = enc_s.mean_s / enc_p.mean_s;
    let dec_speedup = dec_s.mean_s / dec_p.mean_s;
    let line = Json::obj(vec![
        ("bench", Json::str("formats")),
        ("format", Json::str(FormatKind::Nvfp4.name())),
        ("elements", Json::Num(qt.numel() as f64)),
        ("workers", Json::Num(workers as f64)),
        ("encode_scalar_s", Json::Num(enc_s.mean_s)),
        ("encode_parallel_s", Json::Num(enc_p.mean_s)),
        ("encode_speedup", Json::Num(enc_speedup)),
        ("decode_scalar_s", Json::Num(dec_s.mean_s)),
        ("decode_parallel_s", Json::Num(dec_p.mean_s)),
        ("decode_speedup", Json::Num(dec_speedup)),
        ("packed_bytes", Json::Num(packed_bytes as f64)),
        ("dense_f32_bytes", Json::Num(dense_bytes as f64)),
        ("compression_x", Json::Num(dense_bytes as f64 / packed_bytes as f64)),
        ("bits_per_weight", Json::Num(qt.bits_per_weight())),
    ]);
    if let Err(e) = std::fs::write("BENCH_formats.json", format!("{}\n", line.to_string())) {
        eprintln!("[warn] could not write BENCH_formats.json: {e}");
    } else {
        println!(
            "→ wrote BENCH_formats.json (encode {enc_speedup:.2}x, decode {dec_speedup:.2}x \
             with {workers} workers; packed {:.2}x smaller than fp32)",
            dense_bytes as f64 / packed_bytes as f64
        );
    }

    b.finish();
}
