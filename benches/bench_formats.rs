//! Codec throughput: E4M3 / E2M1 / NVFP4 prepare + pack (L3 hot paths of
//! the quantization pipeline). Results land in results/bench/formats.json
//! for the EXPERIMENTS.md §Perf log.

use nvfp4_faar::formats::{e2m1, e4m3, nvfp4};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::rng::Rng;

fn rand_t(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 0.0, 0.05);
    t
}

fn main() {
    let mut b = Bench::new("formats");
    let n = 1 << 20;

    let xs: Vec<f32> = {
        let mut rng = Rng::new(1);
        (0..n).map(|_| rng.normal_f32(0.0, 50.0)).collect()
    };
    b.bench_n("e4m3_encode_1M", n as u64, || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(e4m3::encode(x) as u32);
        }
        black_box(acc);
    });

    let codes: Vec<u8> = (0..n).map(|i| (i % 256) as u8).collect();
    b.bench_n("e4m3_decode_1M", n as u64, || {
        let mut acc = 0.0f32;
        for &c in &codes {
            let v = e4m3::decode(c);
            if v.is_finite() {
                acc += v;
            }
        }
        black_box(acc);
    });

    b.bench_n("e2m1_encode_rtn_1M", n as u64, || {
        let mut acc = 0u32;
        for &x in &xs {
            acc = acc.wrapping_add(e2m1::encode_rtn(x / 60.0) as u32);
        }
        black_box(acc);
    });

    let codes4: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
    b.bench_n("e2m1_pack_unpack_1M", n as u64, || {
        let packed = e2m1::pack(&codes4);
        black_box(e2m1::unpack(&packed, n));
    });

    // weight-tensor level (tiny wq stack: 4 x 128 x 128)
    let w = rand_t(&[4, 128, 128], 2);
    let numel = w.numel() as u64;
    b.bench_n("prepare_4x128x128", numel, || {
        black_box(nvfp4::prepare(&w));
    });

    let p = nvfp4::prepare(&w);
    b.bench_n("rtn_quant_4x128x128", numel, || {
        black_box(nvfp4::rtn_quant(&w, &p));
    });

    let v = p.v_init.map(|x| if x >= 0.5 { 1.0 } else { 0.0 });
    b.bench_n("pack_4x128x128", numel, || {
        black_box(nvfp4::PackedTensor::pack(&w, &p, &v));
    });

    let packed = nvfp4::PackedTensor::pack(&w, &p, &v);
    b.bench_n("unpack_4x128x128", numel, || {
        black_box(packed.unpack());
    });

    b.finish();
}
