//! Rounding + scaling strategy costs (Table 1 / strong-baseline machinery):
//! RTN vs stochastic decisions, 4/6 and search scale selection.

use nvfp4_faar::config::ScaleMethod;
use nvfp4_faar::formats::nvfp4;
use nvfp4_faar::quant::rounding::RoundingScheme;
use nvfp4_faar::quant::{round_with, scaling};
use nvfp4_faar::tensor::Tensor;
use nvfp4_faar::util::bench::{black_box, Bench};
use nvfp4_faar::util::rng::Rng;

fn main() {
    let mut b = Bench::new("rounding");
    let mut rng = Rng::new(3);
    let mut w = Tensor::zeros(&[4, 128, 352]); // tiny w_gate stack
    rng.fill_normal(&mut w.data, 0.0, 0.05);
    let numel = w.numel() as u64;

    for method in [ScaleMethod::Standard, ScaleMethod::FourSix, ScaleMethod::Search] {
        b.bench_n(&format!("scales_{}", method.name()), numel, || {
            black_box(scaling::scales_for(&w, method));
        });
    }

    let p = nvfp4::prepare(&w);
    for scheme in [
        RoundingScheme::Rtn,
        RoundingScheme::Lower,
        RoundingScheme::Upper,
        RoundingScheme::Stochastic(1),
    ] {
        b.bench_n(&format!("round_{}", scheme.name()), numel, || {
            black_box(round_with(&w, &p, scheme));
        });
    }

    b.finish();
}
