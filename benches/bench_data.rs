//! Data substrate: corpus construction/generation, batcher, probe
//! generation, tokenizer round-trip.

use nvfp4_faar::data::{batcher::Split, tasks::TaskKind, Batcher, Corpus, TaskSuite, Tokenizer};
use nvfp4_faar::util::bench::{black_box, Bench};

fn main() {
    let mut b = Bench::new("data");

    b.bench("corpus_build_v512", || {
        black_box(Corpus::by_name("synthwiki", 512).unwrap());
    });

    let c = Corpus::by_name("synthwiki", 512).unwrap();
    b.bench_n("generate_16k_tokens", 16384, || {
        black_box(c.generate(16384, 7));
    });

    let batcher = Batcher::new(&c, Split::Train, 8, 129, 42);
    b.bench_n("batch_8x129", 8 * 129, || {
        black_box(batcher.batch_at(3));
    });

    b.bench("tasks_generate_100_arc_c", || {
        black_box(TaskSuite::generate(TaskKind::ArcChallenge, &c, 100, 16, 1));
    });

    let tok = Tokenizer::new(512);
    let toks: Vec<i32> = (0..512).collect();
    b.bench_n("tokenizer_roundtrip_512", 512, || {
        let text = tok.decode(&toks);
        black_box(tok.encode(&text));
    });

    b.finish();
}
