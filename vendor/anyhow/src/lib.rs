//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access (see the workspace's
//! `rust/src/util/mod.rs`), so the subset of `anyhow` the project uses is
//! implemented here: [`Error`], [`Result`], the [`anyhow!`] and [`bail!`]
//! macros, and the [`Context`] extension trait with `context` /
//! `with_context`. Semantics match anyhow where it matters:
//!
//! * `{}` displays the outermost message only; `{:#}` displays the whole
//!   cause chain joined by `": "` (what `main` prints).
//! * `{:?}` renders the message plus an indented "Caused by" chain.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.
//! * [`Error::new`] keeps the typed value alive so [`Error::downcast_ref`]
//!   can recover it anywhere in the cause chain (the native backend's
//!   KV-exhaustion fallback relies on this).

use std::any::Any;
use std::fmt;

/// A message-chain error value (outermost context first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// the typed error value, when constructed via [`Error::new`]
    obj: Option<Box<dyn Any + Send + Sync>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None, obj: None }
    }

    /// Create an error from a typed error value, preserving it for
    /// [`Error::downcast_ref`] (mirrors `anyhow::Error::new`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: None, obj: Some(Box::new(error)) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)), obj: None }
    }

    /// The typed error value anywhere in the cause chain, if one of the
    /// links was built via [`Error::new`] from an `E`.
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.chain().find_map(|e| e.obj.as_ref()?.downcast_ref::<E>())
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }
}

/// Iterator over an [`Error`]'s cause chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Mirrors anyhow's blanket conversion. `Error` itself deliberately does
// not implement `std::error::Error`, so this cannot overlap with the
// reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error::msg(it.next().unwrap());
        for m in it {
            err = err.context(m);
        }
        err
    }
}

/// Attach context to `Result` / `Option` values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_modes() {
        let e = Error::from(io_err()).context("reading manifest.json");
        assert_eq!(format!("{e}"), "reading manifest.json");
        assert_eq!(format!("{e:#}"), "reading manifest.json: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn typed_errors_downcast_through_context() {
        let e = Error::new(io_err()).context("outer");
        assert_eq!(format!("{e:#}"), "outer: gone");
        assert!(e.downcast_ref::<std::io::Error>().is_some());
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // message-only errors have no typed payload
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn chain_order() {
        let e = Error::msg("root").context("mid").context("top");
        let msgs: Vec<String> = e.chain().map(|x| x.msg.clone()).collect();
        assert_eq!(msgs, ["top", "mid", "root"]);
    }
}
