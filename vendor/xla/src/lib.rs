//! Offline stub of the `xla` PJRT bindings (xla-rs / xla_extension 0.5.1).
//!
//! The offline build environment has no XLA shared library, so this crate
//! provides the exact API surface `nvfp4_faar::runtime` consumes —
//! compiling everywhere and failing *at call time* with a clear error for
//! any operation that would touch PJRT. `PjRtClient::cpu()` succeeds so
//! manifest loading, validation and every pure-rust path (codecs, GPTQ,
//! packing, tests) work without the native backend; only `compile` /
//! `execute` report the backend as unavailable.
//!
//! To enable real graph execution, replace the `xla` path dependency in
//! the workspace `Cargo.toml` with the actual xla-rs crate — the runtime
//! layer is written against its API and needs no source changes.

use std::fmt;
use std::path::Path;

/// Error type matching the shape the runtime layer expects (`Display` for
/// `anyhow!("...: {e}")` interpolation).
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT backend not available in this build \
         (vendor/xla stub — see DESIGN.md §5 to enable the real bindings)"
    )))
}

/// A host-side literal (tuple or typed buffer) fetched from the device.
pub struct Literal;

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// A device buffer owned by the caller.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// The (CPU) PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    /// Succeeds in the stub so `Runtime::load` can parse and validate
    /// manifests without the native library.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Parsed HLO module text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        // Distinguish "file missing" from "backend missing" so load-path
        // failure tests behave like the real crate.
        let p = path.as_ref();
        if !p.exists() {
            return Err(XlaError(format!("{}: no such HLO file", p.display())));
        }
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_loads_but_compile_errs() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&XlaComputation).err().unwrap();
        assert!(err.to_string().contains("not available"));
    }

    #[test]
    fn missing_file_is_a_distinct_error() {
        let err = HloModuleProto::from_text_file("/definitely/not/here.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("no such HLO file"));
    }
}
